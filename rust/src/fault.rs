//! Deterministic fault injection for the campaign layer — the test
//! harness behind the shard supervisor's robustness contract.
//!
//! A [`FaultPlan`] is parsed from `--fault SPEC` (or the `EAFL_FAULT`
//! environment variable, which is how the sweep supervisor arms its
//! shard children). The grammar is a comma-separated list of clauses;
//! each clause is a fault kind followed by `:`-separated `key=value`
//! parameters:
//!
//! ```text
//! crash:after-cells=N            exit(70) after N cells finish in-process
//! stall:ms=M[:cell=NAME]         sleep M ms at a cell's start
//! torn-write:kind=K[:cell=NAME]  write half an artifact, then exit(70)
//! corrupt:kind=K[:cell=NAME]     mangle an artifact's bytes, keep going
//! ```
//!
//! `K` is one of `summary | config | manifest | trace | campaign`.
//! Every clause also accepts two scoping selectors:
//!
//! - `shard=I` — fire only in the process running shard `I` (set via
//!   [`set_shard`] by `campaign::run_campaign`);
//! - `attempt=A` — fire only on supervisor attempt `A` (default `0`,
//!   i.e. the first try; `attempt=all` fires on every retry). The
//!   supervisor exports each child's attempt as `EAFL_FAULT_ATTEMPT`,
//!   which is what lets a retried shard run *unarmed* and converge to
//!   the fault-free bytes.
//!
//! Zero cost when unarmed: every injection site is a single relaxed
//! atomic load + branch, and no site lives on the round hot path (they
//! sit at cell and artifact boundaries), so `plan_path_throughput` is
//! untouched. Injected crashes use exit code [`EXIT_FAULT_CRASH`] so
//! the supervisor (and a human reading an exit status) can tell an
//! injected death from a genuine one.

use std::path::Path;
use std::sync::atomic::{AtomicBool, AtomicU8, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use anyhow::{bail, ensure, Context, Result};

/// Exit code of an injected crash (`crash:` / `torn-write:` clauses) —
/// distinct from genuine failures (1), usage errors (2), cell failures
/// (3) and exhausted retries (4); see `campaign::supervisor`.
pub const EXIT_FAULT_CRASH: i32 = 70;

/// Which campaign artifact a `torn-write` / `corrupt` clause targets.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtifactKind {
    /// A cell's `<name>.summary.json`.
    Summary,
    /// A cell's `<name>.config.toml` fingerprint.
    Config,
    /// The campaign's `<name>.manifest.json`.
    Manifest,
    /// A cell's `<name>.trace.jsonl`.
    Trace,
    /// The merged `<name>.campaign.json` / `.csv`.
    Campaign,
}

impl std::str::FromStr for ArtifactKind {
    type Err = anyhow::Error;
    fn from_str(s: &str) -> Result<Self> {
        Ok(match s {
            "summary" => Self::Summary,
            "config" => Self::Config,
            "manifest" => Self::Manifest,
            "trace" => Self::Trace,
            "campaign" => Self::Campaign,
            other => bail!(
                "unknown artifact kind {other:?} (expected summary|config|manifest|trace|campaign)"
            ),
        })
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum FaultKind {
    Crash,
    Stall,
    TornWrite,
    Corrupt,
}

/// Which supervisor attempt(s) a clause fires on.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum AttemptSel {
    Only(u64),
    All,
}

/// One parsed fault clause; see the module docs for the grammar.
#[derive(Debug, Clone)]
pub struct FaultClause {
    kind: FaultKind,
    /// `crash`: fire once this many cells have finished in-process.
    after_cells: Option<usize>,
    /// `stall`: sleep this long at a matching cell's start.
    stall_ms: Option<u64>,
    /// `torn-write` / `corrupt`: the artifact to hit.
    artifact: Option<ArtifactKind>,
    /// Fire only for this grid cell (artifact faults on cell-less
    /// artifacts — manifest, campaign — never match a cell filter).
    cell: Option<String>,
    /// Fire only in the process running this shard index.
    shard: Option<usize>,
    attempt: AttemptSel,
}

impl FaultClause {
    /// Do this clause's scoping selectors match the current process
    /// (attempt, shard) and the named cell (if any)?
    fn selectors_match(&self, attempt: u64, shard: Option<usize>, cell: Option<&str>) -> bool {
        let attempt_ok = match self.attempt {
            AttemptSel::All => true,
            AttemptSel::Only(a) => a == attempt,
        };
        let shard_ok = match self.shard {
            None => true,
            Some(want) => shard == Some(want),
        };
        let cell_ok = match (&self.cell, cell) {
            (None, _) => true,
            (Some(want), Some(got)) => want == got,
            (Some(_), None) => false,
        };
        attempt_ok && shard_ok && cell_ok
    }
}

/// A parsed, armed fault plan. Torn-write/corrupt clauses fire at most
/// once per process (the `fired` latches); `crash` fires when the
/// in-process finished-cell count reaches its threshold; `stall` fires
/// at every matching cell start.
#[derive(Debug)]
pub struct FaultPlan {
    clauses: Vec<FaultClause>,
    fired: Vec<AtomicBool>,
    /// This process's supervisor attempt (`EAFL_FAULT_ATTEMPT`, 0 on
    /// the first try).
    attempt: u64,
}

impl FaultPlan {
    /// Parse a fault spec. Strict: unknown kinds, unknown or misplaced
    /// parameters, and missing required parameters are all errors, so a
    /// typo'd `--fault` dies at argument parsing (exit 2), not after
    /// hours of sweep.
    pub fn parse(spec: &str) -> Result<FaultPlan> {
        let mut clauses = Vec::new();
        for raw in spec.split(',') {
            let raw = raw.trim();
            if raw.is_empty() {
                continue;
            }
            clauses.push(Self::parse_clause(raw)?);
        }
        ensure!(!clauses.is_empty(), "fault spec is empty");
        let fired = clauses.iter().map(|_| AtomicBool::new(false)).collect();
        Ok(FaultPlan { clauses, fired, attempt: 0 })
    }

    fn parse_clause(raw: &str) -> Result<FaultClause> {
        let mut parts = raw.split(':');
        let kind_str = parts.next().unwrap_or("").trim();
        let kind = match kind_str {
            "crash" => FaultKind::Crash,
            "stall" => FaultKind::Stall,
            "torn-write" => FaultKind::TornWrite,
            "corrupt" => FaultKind::Corrupt,
            other => bail!(
                "unknown fault kind {other:?} in clause {raw:?} \
                 (expected crash|stall|torn-write|corrupt)"
            ),
        };
        let mut clause = FaultClause {
            kind,
            after_cells: None,
            stall_ms: None,
            artifact: None,
            cell: None,
            shard: None,
            attempt: AttemptSel::Only(0),
        };
        for param in parts {
            let (key, value) = param
                .split_once('=')
                .with_context(|| format!("fault parameter {param:?} in {raw:?} is not key=value"))?;
            match key.trim() {
                "after-cells" => {
                    ensure!(
                        kind == FaultKind::Crash,
                        "after-cells only applies to crash (clause {raw:?})"
                    );
                    let n: usize = value
                        .parse()
                        .with_context(|| format!("invalid after-cells {value:?} in {raw:?}"))?;
                    ensure!(n >= 1, "after-cells must be >= 1 (clause {raw:?})");
                    clause.after_cells = Some(n);
                }
                "ms" => {
                    ensure!(
                        kind == FaultKind::Stall,
                        "ms only applies to stall (clause {raw:?})"
                    );
                    clause.stall_ms = Some(
                        value
                            .parse()
                            .with_context(|| format!("invalid ms {value:?} in {raw:?}"))?,
                    );
                }
                "kind" => {
                    ensure!(
                        matches!(kind, FaultKind::TornWrite | FaultKind::Corrupt),
                        "kind only applies to torn-write/corrupt (clause {raw:?})"
                    );
                    clause.artifact = Some(value.parse()?);
                }
                "cell" => clause.cell = Some(value.to_string()),
                "shard" => {
                    clause.shard = Some(
                        value
                            .parse()
                            .with_context(|| format!("invalid shard {value:?} in {raw:?}"))?,
                    );
                }
                "attempt" => {
                    clause.attempt = if value == "all" {
                        AttemptSel::All
                    } else {
                        AttemptSel::Only(value.parse().with_context(|| {
                            format!("invalid attempt {value:?} in {raw:?} (number or \"all\")")
                        })?)
                    };
                }
                other => bail!("unknown fault parameter {other:?} in clause {raw:?}"),
            }
        }
        match kind {
            FaultKind::Crash => {
                ensure!(clause.after_cells.is_some(), "crash needs after-cells=N (clause {raw:?})")
            }
            FaultKind::Stall => {
                ensure!(clause.stall_ms.is_some(), "stall needs ms=M (clause {raw:?})")
            }
            FaultKind::TornWrite | FaultKind::Corrupt => ensure!(
                clause.artifact.is_some(),
                "{kind_str} needs kind=summary|config|manifest|trace|campaign (clause {raw:?})"
            ),
        }
        Ok(clause)
    }

    /// The first unfired torn-write/corrupt clause matching this
    /// artifact write, latched so it fires at most once per process.
    fn claim_write(&self, artifact: ArtifactKind, cell: Option<&str>) -> Option<&FaultClause> {
        let shard = current_shard();
        for (clause, fired) in self.clauses.iter().zip(&self.fired) {
            if !matches!(clause.kind, FaultKind::TornWrite | FaultKind::Corrupt) {
                continue;
            }
            if clause.artifact != Some(artifact)
                || !clause.selectors_match(self.attempt, shard, cell)
            {
                continue;
            }
            if fired.swap(true, Ordering::SeqCst) {
                continue; // already fired in this process
            }
            return Some(clause);
        }
        None
    }
}

/// 0 = not yet initialized, 1 = unarmed (no plan), 2 = armed.
static STATE: AtomicU8 = AtomicU8::new(0);
static PLAN: Mutex<Option<Arc<FaultPlan>>> = Mutex::new(None);
/// Cells finished in this process (the `crash:after-cells` counter).
static CELLS_FINISHED: AtomicUsize = AtomicUsize::new(0);
/// This process's shard index (`usize::MAX` = not a shard).
static SHARD: AtomicUsize = AtomicUsize::new(usize::MAX);

/// The armed fault plan, lazily parsed from `EAFL_FAULT` on first use.
/// The unarmed fast path is one relaxed load + branch. A malformed env
/// spec is reported and ignored here (the CLI validates `--fault` /
/// `EAFL_FAULT` up front and exits 2, so this is a library backstop,
/// not the user-facing error path).
pub fn plan() -> Option<Arc<FaultPlan>> {
    if STATE.load(Ordering::Relaxed) == 1 {
        return None;
    }
    let mut guard = PLAN.lock().unwrap_or_else(|e| e.into_inner());
    if STATE.load(Ordering::Relaxed) == 0 {
        *guard = match std::env::var("EAFL_FAULT") {
            Ok(spec) if !spec.trim().is_empty() => match FaultPlan::parse(&spec) {
                Ok(mut plan) => {
                    plan.attempt = std::env::var("EAFL_FAULT_ATTEMPT")
                        .ok()
                        .and_then(|a| a.parse().ok())
                        .unwrap_or(0);
                    Some(Arc::new(plan))
                }
                Err(e) => {
                    eprintln!("[fault] ignoring malformed EAFL_FAULT {spec:?}: {e:#}");
                    None
                }
            },
            _ => None,
        };
        STATE.store(if guard.is_some() { 2 } else { 1 }, Ordering::SeqCst);
    }
    guard.clone()
}

/// Record which shard this process runs (for `shard=I` clause scoping).
/// Called by `campaign::run_campaign` when the spec carries a shard.
pub fn set_shard(index: usize) {
    SHARD.store(index, Ordering::SeqCst);
}

fn current_shard() -> Option<usize> {
    match SHARD.load(Ordering::SeqCst) {
        usize::MAX => None,
        i => Some(i),
    }
}

fn crash(what: &std::fmt::Arguments<'_>) -> ! {
    eprintln!("[fault] {what} — crashing (exit {EXIT_FAULT_CRASH})");
    std::process::exit(EXIT_FAULT_CRASH);
}

/// Injection site: a grid cell is about to run (`stall` clauses).
pub fn on_cell_start(cell: &str) {
    let Some(plan) = plan() else { return };
    let shard = current_shard();
    for clause in &plan.clauses {
        if clause.kind == FaultKind::Stall
            && clause.selectors_match(plan.attempt, shard, Some(cell))
        {
            let ms = clause.stall_ms.unwrap_or(0);
            eprintln!("[fault] stalling cell {cell} for {ms} ms");
            std::thread::sleep(std::time::Duration::from_millis(ms));
        }
    }
}

/// Injection site: a grid cell finished, artifacts and all (`crash`
/// clauses count finished cells and exit at their threshold).
pub fn on_cell_finished(cell: &str) {
    let Some(plan) = plan() else { return };
    let done = CELLS_FINISHED.fetch_add(1, Ordering::SeqCst) + 1;
    let shard = current_shard();
    for clause in &plan.clauses {
        if clause.kind == FaultKind::Crash
            && clause.selectors_match(plan.attempt, shard, Some(cell))
            && clause.after_cells.map_or(false, |n| done >= n)
        {
            crash(&format_args!("injected crash after {done} finished cell(s), last {cell}"));
        }
    }
}

/// Injection site: every campaign artifact write funnels through here.
/// Unarmed (or unmatched), it is plain `std::fs::write`. A matching
/// `torn-write` clause writes half the bytes and crashes — a power
/// loss mid-write. A matching `corrupt` clause mangles the first byte
/// and *returns success* — silent bit rot the readers must catch.
pub fn write_artifact(
    artifact: ArtifactKind,
    cell: Option<&str>,
    path: &Path,
    text: &str,
) -> Result<()> {
    if let Some(plan) = plan() {
        if let Some(clause) = plan.claim_write(artifact, cell) {
            let bytes = text.as_bytes();
            match clause.kind {
                FaultKind::TornWrite => {
                    let half = bytes.len() / 2;
                    let _ = std::fs::write(path, &bytes[..half]);
                    crash(&format_args!(
                        "torn write: {} truncated to {half}/{} bytes",
                        path.display(),
                        bytes.len()
                    ));
                }
                FaultKind::Corrupt => {
                    let mut mangled = bytes.to_vec();
                    if mangled.is_empty() {
                        mangled.push(b'#');
                    } else {
                        mangled[0] = b'#';
                    }
                    eprintln!("[fault] corrupted {} (first byte mangled)", path.display());
                    return std::fs::write(path, &mangled)
                        .with_context(|| format!("writing {}", path.display()));
                }
                _ => unreachable!("claim_write only returns torn-write/corrupt clauses"),
            }
        }
    }
    std::fs::write(path, text).with_context(|| format!("writing {}", path.display()))
}

/// Injection site: a cell's trace file is complete on disk. The sink
/// buffers and writes incrementally, so trace faults mutate the
/// finished file instead of intercepting the write: `torn-write`
/// truncates it to half and crashes; `corrupt` appends a malformed
/// line and keeps going.
pub fn on_trace_written(cell: &str, path: &Path) {
    let Some(plan) = plan() else { return };
    if let Some(clause) = plan.claim_write(ArtifactKind::Trace, Some(cell)) {
        match clause.kind {
            FaultKind::TornWrite => {
                let len = std::fs::metadata(path).map(|m| m.len()).unwrap_or(0);
                if let Ok(f) = std::fs::OpenOptions::new().write(true).open(path) {
                    let _ = f.set_len(len / 2);
                }
                crash(&format_args!(
                    "torn write: trace {} truncated to {}/{len} bytes",
                    path.display(),
                    len / 2
                ));
            }
            FaultKind::Corrupt => {
                use std::io::Write as _;
                if let Ok(mut f) = std::fs::OpenOptions::new().append(true).open(path) {
                    let _ = f.write_all(b"{\"ev\": \"corrupt");
                }
                eprintln!("[fault] corrupted trace {} (torn tail appended)", path.display());
            }
            _ => {}
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_every_documented_clause_kind() {
        let plan = FaultPlan::parse(
            "crash:after-cells=3, stall:cell=c-1:ms=500, torn-write:kind=summary, \
             corrupt:kind=config:cell=c-2:shard=1:attempt=all",
        )
        .unwrap();
        assert_eq!(plan.clauses.len(), 4);
        assert_eq!(plan.clauses[0].kind, FaultKind::Crash);
        assert_eq!(plan.clauses[0].after_cells, Some(3));
        assert_eq!(plan.clauses[1].stall_ms, Some(500));
        assert_eq!(plan.clauses[1].cell.as_deref(), Some("c-1"));
        assert_eq!(plan.clauses[2].artifact, Some(ArtifactKind::Summary));
        assert_eq!(plan.clauses[3].shard, Some(1));
        assert_eq!(plan.clauses[3].attempt, AttemptSel::All);
    }

    #[test]
    fn rejects_malformed_specs_with_reasons() {
        for (spec, why) in [
            ("", "fault spec is empty"),
            ("explode", "unknown fault kind"),
            ("crash", "after-cells"),
            ("crash:after-cells=0", ">= 1"),
            ("crash:after-cells=x", "invalid after-cells"),
            ("crash:ms=3", "only applies to stall"),
            ("stall:cell=c", "needs ms"),
            ("torn-write", "kind=summary|config|manifest|trace|campaign"),
            ("torn-write:kind=nope", "unknown artifact kind"),
            ("corrupt:kind=config:wat=1", "unknown fault parameter"),
            ("stall:ms", "not key=value"),
            ("crash:after-cells=1:attempt=x", "invalid attempt"),
        ] {
            let err = FaultPlan::parse(spec).unwrap_err().to_string();
            assert!(err.contains(why), "{spec:?}: expected {why:?} in {err:?}");
        }
    }

    #[test]
    fn selectors_scope_by_attempt_shard_and_cell() {
        let plan = FaultPlan::parse("stall:ms=1:cell=c-1:shard=2").unwrap();
        let c = &plan.clauses[0];
        assert!(c.selectors_match(0, Some(2), Some("c-1")));
        assert!(!c.selectors_match(1, Some(2), Some("c-1")), "default attempt is 0");
        assert!(!c.selectors_match(0, Some(1), Some("c-1")), "wrong shard");
        assert!(!c.selectors_match(0, None, Some("c-1")), "not a shard process");
        assert!(!c.selectors_match(0, Some(2), Some("c-2")), "wrong cell");
        assert!(!c.selectors_match(0, Some(2), None), "cell filter needs a cell");

        let all = FaultPlan::parse("crash:after-cells=1:attempt=all").unwrap();
        assert!(all.clauses[0].selectors_match(7, None, Some("anything")));
    }

    #[test]
    fn write_claims_latch_once_per_process() {
        let plan = FaultPlan::parse("corrupt:kind=summary").unwrap();
        assert!(plan.claim_write(ArtifactKind::Summary, Some("c")).is_some());
        assert!(
            plan.claim_write(ArtifactKind::Summary, Some("c")).is_none(),
            "torn/corrupt clauses fire at most once"
        );
        let plan = FaultPlan::parse("torn-write:kind=config:cell=c-1").unwrap();
        assert!(plan.claim_write(ArtifactKind::Summary, Some("c-1")).is_none(), "wrong artifact");
        assert!(plan.claim_write(ArtifactKind::Config, Some("c-2")).is_none(), "wrong cell");
        assert!(plan.claim_write(ArtifactKind::Config, Some("c-1")).is_some());
    }
}
