//! Paper Table 1 — communication energy as a linear function of
//! transfer duration, measured on an HTC Desire HD (Android 2.3),
//! from Kalic et al., MIPRO 2012:
//!
//! |      | Download            | Upload              |
//! |------|---------------------|---------------------|
//! | WiFi | y = 18.09x + 0.17   | y = 21.24x − 2.68   |
//! | 3G   | y = 20.59x − 1.09   | y = 15.31x + 2.67   |
//!
//! `y` is **percent of the HTC's battery** consumed after `x` **hours**
//! on the medium. To apply the measurement to other handsets we convert
//! the percentage to joules through the HTC's capacity (1230 mAh ×
//! 3.7 V) — i.e. we treat Table 1 as an absolute energy-per-hour model
//! of the radio, which transfers across devices, rather than as a
//! percentage model, which would not. The intercepts are clamped at
//! zero energy for very short transfers (the −2.68 / −1.09 intercepts
//! are regression artifacts of the original fit).


use crate::network::Medium;

/// HTC Desire HD battery: 1230 mAh × 3.7 V × 3.6 J/mWh.
pub const HTC_DESIRE_HD_JOULES: f64 = 1230.0 * 3.7 * 3.6;

/// Transfer direction (Table 1 columns).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum CommDirection {
    Download,
    Upload,
}

/// Table 1 coefficients: battery-% = slope · hours + intercept.
pub const fn coefficients(medium: Medium, dir: CommDirection) -> (f64, f64) {
    match (medium, dir) {
        (Medium::Wifi, CommDirection::Download) => (18.09, 0.17),
        (Medium::Wifi, CommDirection::Upload) => (21.24, -2.68),
        (Medium::Cell3G, CommDirection::Download) => (20.59, -1.09),
        (Medium::Cell3G, CommDirection::Upload) => (15.31, 2.67),
    }
}

/// Battery-% of the reference handset consumed by `hours` of transfer
/// (Table 1 applied directly, clamped at 0).
pub fn comm_energy_percent(medium: Medium, dir: CommDirection, hours: f64) -> f64 {
    let (slope, intercept) = coefficients(medium, dir);
    (slope * hours + intercept).max(0.0)
}

/// Energy in joules consumed by `secs` of transfer on `medium`.
pub fn comm_energy_joules(medium: Medium, dir: CommDirection, secs: f64) -> f64 {
    let hours = secs.max(0.0) / 3600.0;
    comm_energy_percent(medium, dir, hours) / 100.0 * HTC_DESIRE_HD_JOULES
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table1_coefficients_pinned() {
        assert_eq!(coefficients(Medium::Wifi, CommDirection::Download), (18.09, 0.17));
        assert_eq!(coefficients(Medium::Wifi, CommDirection::Upload), (21.24, -2.68));
        assert_eq!(coefficients(Medium::Cell3G, CommDirection::Download), (20.59, -1.09));
        assert_eq!(coefficients(Medium::Cell3G, CommDirection::Upload), (15.31, 2.67));
    }

    #[test]
    fn one_hour_wifi_download_is_18_26_percent() {
        // y = 18.09 * 1 + 0.17
        let pct = comm_energy_percent(Medium::Wifi, CommDirection::Download, 1.0);
        assert!((pct - 18.26).abs() < 1e-12);
    }

    #[test]
    fn negative_intercepts_clamp_to_zero() {
        // Very short WiFi upload: 21.24 * ~0 - 2.68 < 0 => clamped.
        assert_eq!(comm_energy_percent(Medium::Wifi, CommDirection::Upload, 0.01), 0.0);
        assert_eq!(comm_energy_joules(Medium::Cell3G, CommDirection::Download, 1.0), 0.0);
    }

    #[test]
    fn joules_conversion_via_htc_capacity() {
        // 1h WiFi download = 18.26% of 16 383.6 J = 2991.6...
        let j = comm_energy_joules(Medium::Wifi, CommDirection::Download, 3600.0);
        let expect = 18.26 / 100.0 * HTC_DESIRE_HD_JOULES;
        assert!((j - expect).abs() < 1e-9);
    }

    #[test]
    fn energy_monotonic_in_duration() {
        let mut last = 0.0;
        for secs in [60.0, 600.0, 1800.0, 3600.0, 7200.0] {
            let j = comm_energy_joules(Medium::Cell3G, CommDirection::Upload, secs);
            assert!(j >= last);
            last = j;
        }
    }
}
