//! Battery accounting and recharge policy — the engine's power phase.
//!
//! Split out of the round loop so energy scenarios plug in without
//! touching the engine: [`BatteryAccounting`] applies the simulated
//! round's energy draws to the registry (participants per the event
//! simulation, bystanders per the background idle/busy model), and a
//! [`RechargePolicy`] decides whether dead devices come back. The
//! wall-clock recharge models (overnight charging windows, solar
//! traces) live in `scenario::recharge` and slot in through the same
//! trait via the experiment's scenario.
//!
//! All battery mutation goes through the registry's guard API
//! (`drain_fl` / `drain_background` / `charge_add` / `recharge_to`), so
//! the SoA pool mirrors and the incremental population aggregates can
//! never drift from the authoritative state — accounting is one of the
//! mutation sites those aggregates are maintained at. The background
//! phase itself is *lazy*: [`BatteryAccounting::drain_background`]
//! advances the registry's drain ledger in O(participants + deaths)
//! and individual batteries materialize the accrued drain on their
//! next touch (`EAFL_EAGER_DRAIN=1` restores the legacy per-round
//! sweep, bit-for-bit).

use crate::config::DeviceConfig;
use crate::sim::ParticipantResult;

use super::registry::Registry;

/// Applies a simulated round's energy draws to the client population.
pub struct BatteryAccounting;

impl BatteryAccounting {
    /// Drain each participant by the energy the event simulation says
    /// it actually spent. `clock_h` is the round's *start* time; a
    /// death lands at the proportional point of the client's timeline.
    /// O(selected).
    pub fn drain_participants(
        registry: &mut Registry,
        results: &[ParticipantResult],
        clock_h: f64,
    ) {
        for r in results {
            let death_time_h = clock_h + r.active_s / 3600.0;
            registry.drain_fl(r.id, r.energy_spent_j, death_time_h);
        }
    }

    /// Background idle/busy drain for every alive non-participant over
    /// the round's wall-clock span ending at `end_clock_h`.
    ///
    /// `sorted_selected` must be sorted ascending (the coordinator
    /// keeps a reusable scratch buffer for this).
    ///
    /// This is a *lazy* epoch advance, O(participants + due deaths):
    /// the registry's drain ledger credits `rate × round_hours` to the
    /// per-class cumsums and fires the death wheel; no battery is
    /// written until its next touch (see `Registry::advance_background`
    /// for the invariant). The `EAFL_EAGER_DRAIN=1` escape hatch tacks
    /// on a full [`Registry::settle_all`] sweep, restoring the legacy
    /// O(N)-per-round materialization — same bits, legacy cost.
    pub fn drain_background(
        registry: &mut Registry,
        sorted_selected: &[usize],
        dev: &DeviceConfig,
        round_hours: f64,
        end_clock_h: f64,
    ) {
        debug_assert!(
            sorted_selected.windows(2).all(|w| w[0] < w[1]),
            "drain_background requires sorted, deduplicated participant ids"
        );
        registry.advance_background(
            sorted_selected,
            dev.idle_drain_per_hour,
            dev.busy_drain_per_hour,
            round_hours,
            end_clock_h,
        );
        if eager_drain_forced() {
            registry.settle_all();
        }
    }
}

/// Whether `EAFL_EAGER_DRAIN=1` (or `true`) forces the legacy eager
/// background-drain sweep: every battery is settled every round
/// instead of on touch. The lazy ledger still runs either way — eager
/// mode only adds the O(N) materialization — so the two modes produce
/// byte-identical campaign reports; the flag exists as an escape hatch
/// and as ci.sh's lazy-vs-eager determinism tier.
///
/// Latched once per process: the environment is read on first call and
/// never again, so a mid-run env mutation (a test harness, a child
/// inheriting a stale shell) cannot flip drain modes between rounds and
/// desync the lazy-ledger invariant mid-campaign.
pub fn eager_drain_forced() -> bool {
    static EAGER: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *EAGER.get_or_init(|| {
        std::env::var("EAFL_EAGER_DRAIN")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false)
    })
}

/// Whether `EAFL_REBUILD_CANDIDATES=1` (or `true`) forces the legacy
/// full-pool candidate rebuild every round instead of the incrementally
/// patched eligible arena (`Registry::refresh_eligible`). The arena is
/// bit-identical to the rebuild by construction — this latch is the
/// escape hatch and ci.sh's incremental-vs-rebuild determinism tier,
/// the exact analogue of [`eager_drain_forced`] for the plan phase.
///
/// Latched once per process for the same reason: flipping candidate
/// maintenance strategies mid-run must be impossible.
pub fn rebuild_candidates_forced() -> bool {
    static REBUILD: std::sync::OnceLock<bool> = std::sync::OnceLock::new();
    *REBUILD.get_or_init(|| {
        std::env::var("EAFL_REBUILD_CANDIDATES")
            .map(|v| v == "1" || v.eq_ignore_ascii_case("true"))
            .unwrap_or(false)
    })
}

/// Pluggable device-recovery model, applied once at the end of every
/// round with the round's wall-clock window `[start_clock_h,
/// end_clock_h)` — wall-clock-keyed policies (overnight charging
/// windows, solar traces in `scenario::recharge`) integrate their
/// charge rate over that span; state-keyed ones (cooldown) only need
/// the end time.
pub trait RechargePolicy: Send {
    fn apply(&self, registry: &mut Registry, start_clock_h: f64, end_clock_h: f64);

    /// Whether this policy can ever bring a dead device back. When
    /// true, the server keeps simulating an all-dead fleet (rounds
    /// still elapse, clocks still advance) so the next charging window
    /// can revive it instead of stopping the experiment early.
    fn can_revive(&self) -> bool {
        false
    }

    fn name(&self) -> &'static str;
}

/// The paper's harsh scenario: a dead device never returns.
pub struct NoRecharge;

impl RechargePolicy for NoRecharge {
    fn apply(&self, _registry: &mut Registry, _start_clock_h: f64, _end_clock_h: f64) {}
    fn name(&self) -> &'static str {
        "none"
    }
}

/// Cooldown recharge: a device dead for at least `after_hours` comes
/// back charged to `to_fraction` of capacity (the config's optional
/// recovery model).
pub struct CooldownRecharge {
    pub after_hours: f64,
    pub to_fraction: f64,
}

impl RechargePolicy for CooldownRecharge {
    fn apply(&self, registry: &mut Registry, _start_clock_h: f64, end_clock_h: f64) {
        // O(dead): only the pool's dead index is scanned, not the whole
        // population — on a healthy fleet this is a no-op over an empty
        // slice. The index iterates in unspecified (swap-remove) order,
        // so collect + sort before mutating to keep revival order — and
        // thus every downstream byte — independent of death history.
        let mut due: Vec<usize> = Vec::new();
        for &id32 in registry.pool().dead.ids() {
            let id = id32 as usize;
            if let Some(died) = registry.client(id).battery.died_at_h {
                if end_clock_h - died >= self.after_hours {
                    due.push(id);
                }
            }
        }
        due.sort_unstable();
        for id in due {
            registry.recharge_to(id, self.to_fraction);
        }
    }
    fn can_revive(&self) -> bool {
        self.to_fraction > 0.0
    }
    fn name(&self) -> &'static str {
        "cooldown"
    }
}

/// The policy the device config asks for.
pub fn recharge_policy_from(dev: &DeviceConfig) -> Box<dyn RechargePolicy> {
    if dev.recharge_after_hours > 0.0 {
        Box::new(CooldownRecharge {
            after_hours: dev.recharge_after_hours,
            to_fraction: dev.recharge_to_fraction,
        })
    } else {
        Box::new(NoRecharge)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{ExperimentConfig, SelectorKind};
    use crate::coordinator::PoolAggregates;
    use crate::sim::FailureKind;

    fn registry() -> Registry {
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        Registry::build(&cfg, 35, 1000)
    }

    #[test]
    fn participants_drain_what_the_sim_spent() {
        let mut r = registry();
        let before = r.client(2).battery.charge_joules();
        let results = vec![ParticipantResult {
            id: 2,
            completed: true,
            failure: None,
            active_s: 120.0,
            energy_spent_j: 50.0,
        }];
        BatteryAccounting::drain_participants(&mut r, &results, 1.0);
        assert!((before - r.client(2).battery.charge_joules() - 50.0).abs() < 1e-9);
        assert!((r.client(2).battery.fl_energy_j - 50.0).abs() < 1e-9);
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
    }

    #[test]
    fn death_timestamp_lands_mid_round() {
        let mut r = registry();
        let cap = r.client(0).battery.capacity_joules();
        let results = vec![ParticipantResult {
            id: 0,
            completed: false,
            failure: Some(FailureKind::BatteryDeath),
            active_s: 1800.0, // died half an hour in
            energy_spent_j: cap * 2.0,
        }];
        BatteryAccounting::drain_participants(&mut r, &results, 10.0);
        assert!(!r.client(0).battery.is_alive());
        assert_eq!(r.client(0).battery.died_at_h, Some(10.5));
        assert_eq!(r.dead_count(), 1);
    }

    #[test]
    fn background_skips_participants_and_dead() {
        let mut r = registry();
        let cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        // Kill client 1.
        let cap = r.client(1).battery.capacity_joules();
        r.drain_fl(1, cap * 2.0, 0.0);
        let charge0 = r.client(0).battery.charge_joules();
        let charge2 = r.client(2).battery.charge_joules();
        BatteryAccounting::drain_background(&mut r, &[0], &cfg.devices, 1.0, 1.0);
        // Lazy drain: the epoch is credited to the ledger, raw batteries
        // stay untouched until materialized.
        assert_eq!(r.client(2).battery.charge_joules(), charge2, "lazy defers the write");
        r.settle_all();
        assert_eq!(r.client(0).battery.charge_joules(), charge0, "participant skipped");
        assert!(r.client(2).battery.charge_joules() < charge2, "bystander drained");
        assert_eq!(r.client(1).battery.background_energy_j, 0.0, "dead skipped");
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
    }

    #[test]
    fn cooldown_recharge_waits_out_the_cooldown() {
        let mut r = registry();
        let cap = r.client(0).battery.capacity_joules();
        r.drain_fl(0, cap * 2.0, 5.0);
        let policy = CooldownRecharge { after_hours: 2.0, to_fraction: 0.8 };
        policy.apply(&mut r, 5.5, 6.0); // only 1 h dead
        assert!(!r.client(0).battery.is_alive());
        policy.apply(&mut r, 7.0, 7.5); // 2.5 h dead
        assert!(r.client(0).battery.is_alive());
        assert!((r.client(0).battery.fraction() - 0.8).abs() < 1e-12);
        assert_eq!(*r.aggregates(), PoolAggregates::recompute(&r));
    }

    #[test]
    fn policy_factory_matches_config() {
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.devices.recharge_after_hours = 0.0;
        assert_eq!(recharge_policy_from(&cfg.devices).name(), "none");
        cfg.devices.recharge_after_hours = 3.0;
        assert_eq!(recharge_policy_from(&cfg.devices).name(), "cooldown");
    }

    #[test]
    fn revival_capability_matches_policy() {
        assert!(!NoRecharge.can_revive());
        assert!(CooldownRecharge { after_hours: 2.0, to_fraction: 0.8 }.can_revive());
        assert!(!CooldownRecharge { after_hours: 2.0, to_fraction: 0.0 }.can_revive());
    }
}
