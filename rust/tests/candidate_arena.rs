//! Incremental eligible-arena equivalence properties: the registry's
//! patched candidate arena (`Registry::refresh_eligible`) must be
//! *bit-identical* — same ids, same ascending order, same bits in every
//! `Candidate` field — to a from-scratch `fill_candidates` rebuild at
//! every round, under randomized interleavings of FL drains (some
//! lethal), lazy background epochs, charges, exact floor-boundary
//! recharges, bans (extended, shortened, and released), link changes
//! and wake-wheel-driven availability flips, across the
//! steady/diurnal/commuter presets and both drain modes (eager is
//! emulated with an explicit per-epoch `settle_all`, since the
//! `EAFL_EAGER_DRAIN=1` latch is process-wide; ci.sh's
//! `EAFL_REBUILD_CANDIDATES=1` pass covers the engine-level latch).

use eafl::config::{ExperimentConfig, SelectorKind};
use eafl::coordinator::{AvailabilityView, Registry};
use eafl::scenario::{Scenario, WakeWheel};
use eafl::selection::Candidate;
use eafl::util::prop::forall;
use eafl::util::rng::Rng;

/// Bit-exact candidate-slice equality: ids, order, every field.
fn assert_bit_identical(got: &[Candidate], want: &[Candidate], ctx: &str) {
    assert_eq!(
        got.len(),
        want.len(),
        "{ctx}: candidate counts differ (arena {} vs rebuild {})",
        got.len(),
        want.len()
    );
    for (a, b) in got.iter().zip(want) {
        assert_eq!(a.id, b.id, "{ctx}: membership/order diverged");
        assert_eq!(
            a.stat_util.map(f64::to_bits),
            b.stat_util.map(f64::to_bits),
            "{ctx}: stat_util at id {}",
            a.id
        );
        assert_eq!(
            a.measured_duration_s.map(f64::to_bits),
            b.measured_duration_s.map(f64::to_bits),
            "{ctx}: measured_duration_s at id {}",
            a.id
        );
        assert_eq!(
            a.expected_duration_s.to_bits(),
            b.expected_duration_s.to_bits(),
            "{ctx}: expected_duration_s at id {}",
            a.id
        );
        assert_eq!(
            a.last_selected_round, b.last_selected_round,
            "{ctx}: last_selected_round at id {}",
            a.id
        );
        assert_eq!(
            a.battery_frac.to_bits(),
            b.battery_frac.to_bits(),
            "{ctx}: battery_frac at id {} ({} vs {})",
            a.id,
            a.battery_frac,
            b.battery_frac
        );
        assert_eq!(
            a.projected_drain_frac.to_bits(),
            b.projected_drain_frac.to_bits(),
            "{ctx}: projected_drain_frac at id {}",
            a.id
        );
        assert_eq!(
            a.round_energy_j.to_bits(),
            b.round_energy_j.to_bits(),
            "{ctx}: round_energy_j at id {}",
            a.id
        );
    }
}

/// One randomized campaign against one preset: every round the arena is
/// refreshed, compared bit-for-bit against the rebuild, and then the
/// state is perturbed through every mutation family the arena must
/// track.
fn drive(preset: &str, eager: bool, cases: u64) {
    forall(cases, |rng| {
        let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
        cfg.federation.num_clients = rng.gen_range_usize(6, 48);
        cfg.devices.seed = rng.next_u64();
        cfg.network.seed = rng.next_u64();
        cfg.data.seed = rng.next_u64();
        cfg.data.min_samples = 3;
        cfg.data.max_samples = 8;
        let n = cfg.federation.num_clients;
        let scenario = Scenario::preset(preset).expect("known preset");
        let env = scenario.build_env(rng.next_u64(), n, &cfg.devices);
        let mut r = Registry::build(&cfg, 35, 1000);

        // Half the cases pin the floor to an exact binary fraction so
        // the boundary recharges below land on it bit-for-bit.
        let floor = if rng.gen_bool(0.5) { 0.25 } else { rng.gen_range_f64(0.0, 0.4) };
        let always = env.availability.is_always_available();
        let mut wake =
            (!always).then(|| WakeWheel::new(env.availability.as_ref(), n, 0.0));
        let mut clock = 0.0f64;
        let mut reference = Vec::new();
        let rounds = rng.gen_range_usize(8, 25) as u64;
        for round in 1..=rounds {
            // The engine's per-round order: advance the wake wheel to
            // the round clock, refresh the arena, plan.
            if let Some(w) = wake.as_mut() {
                w.advance(env.availability.as_ref(), clock);
            }
            match wake.as_ref() {
                None => {
                    r.refresh_eligible(round, floor, AvailabilityView::AlwaysOn);
                    r.fill_candidates(round, floor, |_| true, &mut reference);
                }
                Some(w) => {
                    r.refresh_eligible(
                        round,
                        floor,
                        AvailabilityView::Cached { bits: w.avail(), changed: w.changed() },
                    );
                    let bits = w.avail();
                    r.fill_candidates(round, floor, |id| bits[id], &mut reference);
                }
            }
            assert_bit_identical(
                r.eligible(),
                &reference,
                &format!("{preset} eager={eager} round {round}"),
            );

            // Perturb between rounds.
            for _ in 0..rng.gen_range_usize(0, 5) {
                let id = rng.gen_range_usize(0, n - 1);
                let cap = r.client(id).battery.capacity_joules();
                match rng.gen_range_usize(0, 7) {
                    // Lazy background epoch with random participants —
                    // moves the cumsums, fires death + floor wheels.
                    0 | 1 => {
                        let hours = rng.gen_range_f64(0.05, 1.0);
                        let participants: Vec<usize> =
                            (0..n).filter(|_| rng.gen_bool(0.15)).collect();
                        clock += hours;
                        r.advance_background(
                            &participants,
                            rng.gen_range_f64(0.0, 0.05),
                            rng.gen_range_f64(0.0, 0.1),
                            hours,
                            clock,
                        );
                        if eager {
                            r.settle_all();
                        }
                    }
                    // FL drain — sometimes lethal.
                    2 => {
                        let e = cap * rng.gen_range_f64(0.0, 1.6);
                        r.drain_fl(id, e, clock);
                    }
                    // Charge / revive.
                    3 => r.charge_add(id, cap * rng.gen_range_f64(0.0, 0.6)),
                    // Exact floor-boundary recharge: frac == floor
                    // bit-for-bit, which the strict `>` must exclude.
                    4 => r.recharge_to(id, floor),
                    // Ban churn: fresh bans, extensions, shortenings,
                    // and already-expired values.
                    5 => {
                        let until = match rng.gen_range_usize(0, 3) {
                            0 => round + rng.gen_range_usize(1, 6) as u64,
                            1 => round, // expires immediately (not banned)
                            _ => round.saturating_sub(1),
                        };
                        r.stats_mut(id).banned_until_round = until;
                    }
                    // Selection stats (candidate payload fields).
                    6 => {
                        let mut s = r.stats_mut(id);
                        s.stat_util = Some(rng.gen_range_f64(0.1, 90.0));
                        s.measured_duration_s = Some(rng.gen_range_f64(5.0, 500.0));
                        s.last_selected_round = Some(round);
                        s.times_selected += 1;
                    }
                    // Link migration — reprojects through the guard.
                    _ => {
                        r.link_mut(id).up_mbps *= rng.gen_range_f64(0.5, 1.5);
                    }
                }
            }
        }
    });
}

#[test]
fn prop_arena_matches_rebuild_steady_lazy() {
    drive("steady", false, 12);
}

#[test]
fn prop_arena_matches_rebuild_steady_eager() {
    drive("steady", true, 8);
}

#[test]
fn prop_arena_matches_rebuild_diurnal_lazy() {
    drive("diurnal", false, 12);
}

#[test]
fn prop_arena_matches_rebuild_diurnal_eager() {
    drive("diurnal", true, 8);
}

#[test]
fn prop_arena_matches_rebuild_commuter_lazy() {
    drive("commuter", false, 12);
}

#[test]
fn prop_arena_matches_rebuild_commuter_eager() {
    drive("commuter", true, 8);
}

/// A floor change mid-run forces a rebuild instead of a stale patch —
/// the arena is keyed to the floor it was built for.
#[test]
fn floor_change_forces_a_rebuild() {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = 12;
    cfg.data.min_samples = 3;
    cfg.data.max_samples = 8;
    let mut r = Registry::build(&cfg, 35, 1000);
    let mut reference = Vec::new();

    r.refresh_eligible(1, 0.01, AvailabilityView::AlwaysOn);
    r.fill_candidates(1, 0.01, |_| true, &mut reference);
    assert_bit_identical(r.eligible(), &reference, "floor 0.01");

    // Drain a few clients into the band between the two floors, then
    // raise the floor: membership must contract accordingly.
    for id in 0..4 {
        let cap = r.client(id).battery.capacity_joules();
        let frac = r.effective_battery_frac(id);
        r.drain_fl(id, cap * (frac - 0.2), 0.5);
    }
    r.refresh_eligible(2, 0.5, AvailabilityView::AlwaysOn);
    r.fill_candidates(2, 0.5, |_| true, &mut reference);
    assert_bit_identical(r.eligible(), &reference, "floor 0.5");
    for id in 0..4 {
        assert!(
            r.eligible().iter().all(|c| c.id != id),
            "client {id} sits under the raised floor"
        );
    }
}

/// Deterministic worst case for the floor wheel: a staircase of charges
/// drained at a fixed rate crosses the floor one client per epoch. The
/// arena must evict each client on exactly the epoch its drain-effective
/// fraction stops being strictly above the floor — the wheel may fire
/// early (re-armed, harmless) but never late. Membership is checked
/// against the closed-form fraction itself, so the assertion is exact
/// wherever the floating-point boundary actually lands.
#[test]
fn floor_crossings_fire_on_the_exact_epoch() {
    let mut cfg = ExperimentConfig::smoke(SelectorKind::Eafl);
    cfg.federation.num_clients = 8;
    cfg.data.min_samples = 3;
    cfg.data.max_samples = 8;
    let n = cfg.federation.num_clients;
    let mut r = Registry::build(&cfg, 35, 1000);
    let floor = 0.25;
    // Client `id` starts at floor + (id+1)/1024: with a drain rate of
    // 1/1024 per hour it sits strictly above the floor for exactly
    // `id + 1` one-hour epochs (all quantities exact binary fractions).
    for id in 0..n {
        r.recharge_to(id, floor + (id + 1) as f64 / 1024.0);
    }
    let rate = 1.0 / 1024.0;
    let mut reference = Vec::new();
    r.refresh_eligible(1, floor, AvailabilityView::AlwaysOn);
    r.fill_candidates(1, floor, |_| true, &mut reference);
    assert_bit_identical(r.eligible(), &reference, "epoch 0");
    assert_eq!(r.eligible().len(), n);

    for epoch in 1..=n as u64 + 1 {
        r.advance_background(&[], rate, rate, 1.0, epoch as f64);
        let round = epoch + 1;
        r.refresh_eligible(round, floor, AvailabilityView::AlwaysOn);
        r.fill_candidates(round, floor, |_| true, &mut reference);
        assert_bit_identical(r.eligible(), &reference, &format!("epoch {epoch}"));
        // The wheel must never be late: membership equals the exact
        // strictly-above predicate over the closed-form fraction.
        let expect: Vec<usize> =
            (0..n).filter(|&id| r.effective_battery_frac(id) > floor).collect();
        let got: Vec<usize> = r.eligible().iter().map(|c| c.id).collect();
        assert_eq!(got, expect, "late or phantom floor crossing at epoch {epoch}");
        assert!(
            r.eligible().len() <= n.saturating_sub(epoch as usize - 1),
            "staircase must shed roughly one client per epoch"
        );
    }
    // The full staircase is at least 1/1024 under the floor by the end
    // — a margin no rounding can blur.
    assert!(r.eligible().is_empty());
}
