//! Minimal benchmarking harness (offline stand-in for criterion).
//!
//! Auto-calibrates iteration counts to a target measurement time, runs
//! warmup + measured batches, and reports min/mean/median/p95 per
//! iteration. Used by every target in `benches/` (declared with
//! `harness = false`).
//!
//! Results can be emitted as machine-readable `BENCH_*.json`
//! ([`Bench::write_json`], schema [`BENCH_SCHEMA`]) so the repo's perf
//! trajectory is recorded run over run instead of scrolling away in a
//! terminal — `make bench` writes `BENCH_plan.json` at the repo root
//! and ci.sh smoke-checks the schema.

use std::collections::BTreeMap;
use std::hint::black_box;
use std::path::Path;
use std::time::{Duration, Instant};

use anyhow::{Context, Result};

use crate::util::json::Json;

/// Schema tag stamped into every emitted bench JSON document.
pub const BENCH_SCHEMA: &str = "eafl-bench-v1";

/// One benchmark's timing statistics (nanoseconds per iteration).
#[derive(Debug, Clone)]
pub struct BenchStats {
    pub name: String,
    pub iterations: u64,
    pub min_ns: f64,
    pub mean_ns: f64,
    pub median_ns: f64,
    pub p95_ns: f64,
}

impl BenchStats {
    /// One JSON row of the emitted results array.
    pub fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".to_string(), Json::Str(self.name.clone()));
        m.insert("iterations".to_string(), Json::Num(self.iterations as f64));
        m.insert("min_ns".to_string(), Json::Num(self.min_ns));
        m.insert("mean_ns".to_string(), Json::Num(self.mean_ns));
        m.insert("median_ns".to_string(), Json::Num(self.median_ns));
        m.insert("p95_ns".to_string(), Json::Num(self.p95_ns));
        Json::Obj(m)
    }

    pub fn report(&self) {
        println!(
            "{:<44} {:>10} iters  min {:>12}  mean {:>12}  median {:>12}  p95 {:>12}",
            self.name,
            self.iterations,
            fmt_ns(self.min_ns),
            fmt_ns(self.mean_ns),
            fmt_ns(self.median_ns),
            fmt_ns(self.p95_ns),
        );
    }
}

fn fmt_ns(ns: f64) -> String {
    if ns < 1e3 {
        format!("{ns:.1} ns")
    } else if ns < 1e6 {
        format!("{:.2} µs", ns / 1e3)
    } else if ns < 1e9 {
        format!("{:.2} ms", ns / 1e6)
    } else {
        format!("{:.3} s", ns / 1e9)
    }
}

/// Benchmark runner with a fixed time budget per benchmark.
pub struct Bench {
    /// Target total measurement time.
    pub measure_time: Duration,
    /// Warmup time before measuring.
    pub warmup_time: Duration,
    /// Number of measured batches (samples).
    pub samples: usize,
    results: Vec<BenchStats>,
}

impl Default for Bench {
    fn default() -> Self {
        Self::new()
    }
}

impl Bench {
    pub fn new() -> Self {
        Self {
            measure_time: Duration::from_secs(2),
            warmup_time: Duration::from_millis(300),
            samples: 20,
            results: Vec::new(),
        }
    }

    /// Quick harness for heavy end-to-end benches.
    pub fn heavy() -> Self {
        Self {
            measure_time: Duration::from_secs(4),
            warmup_time: Duration::from_millis(0),
            samples: 3,
            results: Vec::new(),
        }
    }

    /// Sub-second budget for CI smoke runs (numbers are indicative
    /// only — the point is that the path executes and emits JSON).
    pub fn smoke() -> Self {
        Self {
            measure_time: Duration::from_millis(400),
            warmup_time: Duration::from_millis(50),
            samples: 4,
            results: Vec::new(),
        }
    }

    /// Measure `f`, which performs ONE logical iteration per call.
    pub fn run<F: FnMut()>(&mut self, name: &str, mut f: F) -> &BenchStats {
        // Warmup + calibration: how many iterations fit in a batch?
        let calib_start = Instant::now();
        let mut calib_iters = 0u64;
        loop {
            f();
            calib_iters += 1;
            if calib_start.elapsed() >= self.warmup_time.max(Duration::from_millis(50)) {
                break;
            }
        }
        let per_iter = calib_start.elapsed().as_secs_f64() / calib_iters as f64;
        let budget_per_sample = self.measure_time.as_secs_f64() / self.samples as f64;
        let iters_per_sample = ((budget_per_sample / per_iter).ceil() as u64).max(1);

        // Measured batches.
        let mut batch_ns: Vec<f64> = Vec::with_capacity(self.samples);
        for _ in 0..self.samples {
            let t0 = Instant::now();
            for _ in 0..iters_per_sample {
                f();
            }
            batch_ns.push(t0.elapsed().as_nanos() as f64 / iters_per_sample as f64);
        }
        batch_ns.sort_by(f64::total_cmp);
        let stats = BenchStats {
            name: name.to_string(),
            iterations: iters_per_sample * self.samples as u64,
            min_ns: batch_ns[0],
            mean_ns: batch_ns.iter().sum::<f64>() / batch_ns.len() as f64,
            median_ns: batch_ns[batch_ns.len() / 2],
            p95_ns: batch_ns[((batch_ns.len() as f64 * 0.95) as usize).min(batch_ns.len() - 1)],
        };
        stats.report();
        self.results.push(stats);
        self.results.last().unwrap()
    }

    /// Run a function once and report its wall time (for long
    /// end-to-end benches where iteration is meaningless).
    pub fn run_once<T, F: FnOnce() -> T>(&mut self, name: &str, f: F) -> T {
        let t0 = Instant::now();
        let out = black_box(f());
        let ns = t0.elapsed().as_nanos() as f64;
        let stats = BenchStats {
            name: name.to_string(),
            iterations: 1,
            min_ns: ns,
            mean_ns: ns,
            median_ns: ns,
            p95_ns: ns,
        };
        stats.report();
        self.results.push(stats);
        out
    }

    pub fn results(&self) -> &[BenchStats] {
        &self.results
    }

    /// The collected results as a `eafl-bench-v1` JSON document:
    /// `{"schema", "bench", "results": [...], "derived": {...}}`.
    /// `derived` carries bench-specific computed figures (speedups,
    /// per-round costs) keyed by name; pass an empty slice when there
    /// are none.
    pub fn to_json(&self, bench: &str, derived: &[(&str, f64)]) -> Json {
        let mut m = BTreeMap::new();
        m.insert("schema".to_string(), Json::Str(BENCH_SCHEMA.to_string()));
        m.insert("bench".to_string(), Json::Str(bench.to_string()));
        m.insert(
            "results".to_string(),
            Json::Arr(self.results.iter().map(BenchStats::to_json).collect()),
        );
        let mut d = BTreeMap::new();
        for (k, v) in derived {
            d.insert(k.to_string(), Json::Num(*v));
        }
        m.insert("derived".to_string(), Json::Obj(d));
        Json::Obj(m)
    }

    /// Write the `eafl-bench-v1` document to `path`.
    pub fn write_json(&self, bench: &str, derived: &[(&str, f64)], path: &Path) -> Result<()> {
        let doc = self.to_json(bench, derived).to_string_pretty();
        std::fs::write(path, doc.as_bytes())
            .with_context(|| format!("writing bench JSON to {}", path.display()))?;
        Ok(())
    }
}

/// Re-export for benches to keep the optimizer honest.
pub use std::hint::black_box as bb;

// ---------------------------------------------------------------------------
// Perf trend rendering (BENCH_history.jsonl -> table)
// ---------------------------------------------------------------------------

/// Output format for [`render_trend`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum TrendFormat {
    Markdown,
    Csv,
}

/// Short display form of a recorded SHA: ten hex chars, keeping the
/// `-dirty` marker `append_bench_history.sh` stamps on unclean trees.
fn short_sha(sha: &str) -> String {
    let (hex, dirty) = match sha.strip_suffix("-dirty") {
        Some(hex) => (hex, "-dirty"),
        None => (sha, ""),
    };
    let short: String = hex.chars().take(10).collect();
    format!("{short}{dirty}")
}

/// Render `BENCH_history.jsonl` (one `{"sha": ..., "bench": <eafl-bench-v1>}`
/// object per line, appended per commit by `scripts/append_bench_history.sh`)
/// as a per-commit trend table: one row per recorded entry in file
/// order, one column per benchmark name in first-seen order, cells the
/// mean per-iteration time in milliseconds. Benchmarks that appear in
/// some commits but not others (added or renamed over time) leave their
/// missing cells blank rather than erroring — the history spans the
/// repo's whole life.
pub fn render_trend(history: &str, format: TrendFormat) -> Result<String> {
    let mut columns: Vec<String> = Vec::new();
    let mut rows: Vec<(String, BTreeMap<String, f64>)> = Vec::new();
    for (idx, line) in history.lines().enumerate() {
        let line = line.trim();
        if line.is_empty() {
            continue;
        }
        let json = Json::parse(line)
            .with_context(|| format!("bench history line {}: invalid JSON", idx + 1))?;
        let sha = json
            .get("sha")
            .and_then(Json::as_str)
            .ok_or_else(|| anyhow::anyhow!("bench history line {}: missing \"sha\"", idx + 1))?;
        let results = json
            .get("bench")
            .and_then(|b| b.get("results"))
            .and_then(Json::as_arr)
            .ok_or_else(|| {
                anyhow::anyhow!("bench history line {}: missing bench.results", idx + 1)
            })?;
        let mut means = BTreeMap::new();
        for r in results {
            let (Some(name), Some(mean_ns)) = (
                r.get("name").and_then(Json::as_str),
                r.get("mean_ns").and_then(Json::as_f64),
            ) else {
                continue;
            };
            if !columns.iter().any(|c| c == name) {
                columns.push(name.to_string());
            }
            means.insert(name.to_string(), mean_ns);
        }
        rows.push((short_sha(sha), means));
    }
    anyhow::ensure!(
        !rows.is_empty(),
        "bench history is empty — run `make bench` to record the first entry"
    );
    let mut out = String::new();
    match format {
        TrendFormat::Markdown => {
            out.push_str("| sha |");
            for c in &columns {
                out.push_str(&format!(" {c} (ms) |"));
            }
            out.push_str("\n|---|");
            for _ in &columns {
                out.push_str("---:|");
            }
            out.push('\n');
            for (sha, means) in &rows {
                out.push_str(&format!("| {sha} |"));
                for c in &columns {
                    match means.get(c) {
                        Some(ns) => out.push_str(&format!(" {:.3} |", ns / 1e6)),
                        None => out.push_str(" — |"),
                    }
                }
                out.push('\n');
            }
        }
        TrendFormat::Csv => {
            out.push_str("sha");
            for c in &columns {
                out.push_str(&format!(",{c}_ms"));
            }
            out.push('\n');
            for (sha, means) in &rows {
                out.push_str(sha);
                for c in &columns {
                    match means.get(c) {
                        Some(ns) => out.push_str(&format!(",{:.6}", ns / 1e6)),
                        None => out.push(','),
                    }
                }
                out.push('\n');
            }
        }
    }
    Ok(out)
}

// ---------------------------------------------------------------------------
// Bench CLI flag parsing
// ---------------------------------------------------------------------------
//
// Bench targets are `harness = false` binaries with hand-rolled flag
// loops. These helpers give them the same failure mode as the main CLI:
// a malformed flag is a one-line error the bench turns into a non-zero
// exit with clean stderr — never an `.expect` panic with a backtrace.

/// The value following `flag`, or a clear error naming the flag.
pub fn require_value(flag: &str, value: Option<String>) -> Result<String> {
    value.ok_or_else(|| anyhow::anyhow!("{flag} requires a value"))
}

/// Parse a comma-separated list of positive client counts, bounded by
/// [`crate::config::MAX_CLIENTS`] (the same ceiling the experiment
/// config enforces — a bench must not be the one path that can ask the
/// allocator for an absurd population).
pub fn parse_count_list(flag: &str, raw: &str) -> Result<Vec<usize>> {
    let mut out = Vec::new();
    for part in raw.split(',') {
        let part = part.trim();
        if part.is_empty() {
            continue;
        }
        let n: usize = part
            .parse()
            .map_err(|_| anyhow::anyhow!("{flag}: invalid count {part:?}"))?;
        anyhow::ensure!(n > 0, "{flag}: counts must be > 0 (got {part:?})");
        anyhow::ensure!(
            n <= crate::config::MAX_CLIENTS,
            "{flag}: counts must be <= {} (got {part:?})",
            crate::config::MAX_CLIENTS
        );
        out.push(n);
    }
    anyhow::ensure!(!out.is_empty(), "{flag} needs at least one count");
    Ok(out)
}

/// Parse a comma-separated list of non-empty names (e.g. scenarios).
pub fn parse_name_list(flag: &str, raw: &str) -> Result<Vec<String>> {
    let out: Vec<String> = raw
        .split(',')
        .map(|s| s.trim().to_string())
        .filter(|s| !s.is_empty())
        .collect();
    anyhow::ensure!(!out.is_empty(), "{flag} needs at least one name");
    Ok(out)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measures_something_positive() {
        let mut b = Bench {
            measure_time: Duration::from_millis(80),
            warmup_time: Duration::from_millis(10),
            samples: 4,
            results: Vec::new(),
        };
        let mut acc = 0u64;
        let stats = b.run("noop-ish", || {
            acc = bb(acc.wrapping_add(1));
        });
        assert!(stats.min_ns > 0.0);
        assert!(stats.p95_ns >= stats.median_ns);
        assert!(stats.iterations > 0);
    }

    #[test]
    fn run_once_returns_value() {
        let mut b = Bench::heavy();
        let v = b.run_once("compute", || 21 * 2);
        assert_eq!(v, 42);
        assert_eq!(b.results().len(), 1);
    }

    #[test]
    fn fmt_ns_scales() {
        assert!(fmt_ns(500.0).contains("ns"));
        assert!(fmt_ns(5e4).contains("µs"));
        assert!(fmt_ns(5e7).contains("ms"));
        assert!(fmt_ns(5e9).contains("s"));
    }

    #[test]
    fn flag_helpers_accept_good_input() {
        assert_eq!(require_value("--out", Some("x.json".into())).unwrap(), "x.json");
        assert_eq!(
            parse_count_list("--clients", "10, 20,30").unwrap(),
            vec![10, 20, 30]
        );
        assert_eq!(
            parse_name_list("--scenarios", "steady, diurnal").unwrap(),
            vec!["steady".to_string(), "diurnal".to_string()]
        );
    }

    #[test]
    fn flag_helpers_reject_malformed_input_with_the_flag_name() {
        let e = require_value("--out", None).unwrap_err().to_string();
        assert!(e.contains("--out"), "{e}");
        for raw in ["abc", "10,abc", "", "0", "-5", "10,,0"] {
            let e = parse_count_list("--clients", raw).unwrap_err().to_string();
            assert!(e.contains("--clients"), "{raw:?}: {e}");
        }
        let huge = format!("{}", crate::config::MAX_CLIENTS + 1);
        let e = parse_count_list("--clients", &huge).unwrap_err().to_string();
        assert!(e.contains("must be <="), "{e}");
        let e = parse_name_list("--scenarios", " , ").unwrap_err().to_string();
        assert!(e.contains("--scenarios"), "{e}");
    }

    #[test]
    fn render_trend_builds_per_commit_tables() {
        let history = concat!(
            r#"{"sha": "aaaaaaaaaaaaaaaa", "bench": {"schema": "eafl-bench-v1", "results": [{"name": "plan_path", "mean_ns": 2000000.0}]}}"#,
            "\n",
            r#"{"sha": "bbbbbbbbbbbbbbbb-dirty", "bench": {"schema": "eafl-bench-v1", "results": [{"name": "plan_path", "mean_ns": 1000000.0}, {"name": "merge", "mean_ns": 500000.0}]}}"#,
            "\n",
        );
        let md = render_trend(history, TrendFormat::Markdown).unwrap();
        // Short SHAs, dirty marker preserved, columns in first-seen order.
        assert!(md.contains("| aaaaaaaaaa |"), "{md}");
        assert!(md.contains("| bbbbbbbbbb-dirty |"), "{md}");
        assert!(md.contains("| plan_path (ms) | merge (ms) |"), "{md}");
        // Means in ms; the first entry predates the merge bench -> blank cell.
        assert!(md.contains("| 2.000 | — |"), "{md}");
        assert!(md.contains("| 1.000 | 0.500 |"), "{md}");

        let csv = render_trend(history, TrendFormat::Csv).unwrap();
        let lines: Vec<&str> = csv.lines().collect();
        assert_eq!(lines[0], "sha,plan_path_ms,merge_ms");
        assert_eq!(lines[1], "aaaaaaaaaa,2.000000,");
        assert_eq!(lines[2], "bbbbbbbbbb-dirty,1.000000,0.500000");
    }

    #[test]
    fn render_trend_rejects_empty_or_malformed_history() {
        let e = render_trend("", TrendFormat::Markdown).unwrap_err().to_string();
        assert!(e.contains("history"), "{e}");
        let e = render_trend("not json\n", TrendFormat::Csv).unwrap_err().to_string();
        assert!(e.contains("line 1"), "{e}");
        let e = render_trend(r#"{"sha": "x"}"#, TrendFormat::Csv).unwrap_err().to_string();
        assert!(e.contains("bench.results"), "{e}");
    }

    #[test]
    fn json_emission_matches_schema() {
        let mut b = Bench::heavy();
        b.run_once("unit", || 1 + 1);
        let doc = b.to_json("smoke", &[("speedup", 12.5)]);
        assert_eq!(doc.get("schema").and_then(Json::as_str), Some(BENCH_SCHEMA));
        assert_eq!(doc.get("bench").and_then(Json::as_str), Some("smoke"));
        let results = doc.get("results").and_then(Json::as_arr).unwrap();
        assert_eq!(results.len(), 1);
        for key in ["name", "iterations", "min_ns", "mean_ns", "median_ns", "p95_ns"] {
            assert!(results[0].get(key).is_some(), "missing results[].{key}");
        }
        let derived = doc.get("derived").and_then(Json::as_obj).unwrap();
        assert_eq!(derived.get("speedup").and_then(Json::as_f64), Some(12.5));
        // The document round-trips through the in-tree parser.
        let reparsed = Json::parse(&doc.to_string_pretty()).unwrap();
        assert_eq!(reparsed, doc);
    }
}
