//! Client-presence models — who is reachable when a round is planned.
//!
//! [`PlanPhase`](crate::coordinator::PlanPhase) intersects the
//! selector's candidate pool with the scenario's availability model, so
//! churn is an environment property, not a selector concern. Every
//! model is a pure function of (seed, client, simulated time): no
//! mutable state is touched during a run, which is what keeps campaign
//! results byte-identical at any worker count.

use crate::util::rng::Rng;
use crate::util::wheel::BucketWheel;

use super::hash01;

/// Presence granularity: availability is resampled once per slot, so
/// nearby rounds see a coherent on/off state instead of per-call noise.
const DIURNAL_SLOT_H: f64 = 0.25;

/// Which clients are present (powered on, reachable, willing) at a
/// point in simulated time. Implementations must be deterministic and
/// side-effect free — the engine may consult them in any order.
pub trait AvailabilityModel: Send + Sync {
    /// Whether client `id` can be planned into a round starting at
    /// wall-clock `clock_h` (hours since experiment start).
    fn available(&self, id: usize, clock_h: f64) -> bool;

    /// Hint that `available` is constantly true — lets the plan phase
    /// skip the per-client dynamic dispatch entirely on the steady
    /// scenario's million-client candidate scan (the analogue of
    /// `NetworkModel::is_static`).
    fn is_always_available(&self) -> bool {
        false
    }

    /// Earliest wall-clock hour at which `available(id, ·)` *may* next
    /// differ from its value at `clock_h` — the [`WakeWheel`]'s
    /// re-evaluation contract.
    ///
    /// Must be a **sound lower bound**: the model guarantees the
    /// client's availability is constant on `[clock_h, t)` for the
    /// returned `t`. Returning a time earlier than the true change is
    /// fine (the wheel just re-evaluates and re-arms); returning one
    /// later than a change would let the cached availability go stale
    /// and is a correctness bug. `None` means the client's availability
    /// never changes again. The conservative default, `Some(clock_h)`,
    /// degrades the wheel to re-evaluating every client every round —
    /// always sound, never fast.
    fn next_change_h(&self, _id: usize, clock_h: f64) -> Option<f64> {
        Some(clock_h)
    }

    fn name(&self) -> &'static str;
}

/// The paper's implicit environment: every alive client is reachable
/// every round.
pub struct AlwaysOn;

impl AvailabilityModel for AlwaysOn {
    fn available(&self, _id: usize, _clock_h: f64) -> bool {
        true
    }
    fn is_always_available(&self) -> bool {
        true
    }
    fn next_change_h(&self, _id: usize, _clock_h: f64) -> Option<f64> {
        None // never changes — the wheel stays empty
    }
    fn name(&self) -> &'static str {
        "always-on"
    }
}

/// Sine-wave diurnal presence: the probability that a client is online
/// peaks at `peak_hour` (wall-clock hour of day) and bottoms out twelve
/// hours later, with a per-client phase offset so the population does
/// not churn in lock-step.
pub struct DiurnalAvailability {
    pub seed: u64,
    /// Hour of day (0..24) at which presence probability is maximal.
    pub peak_hour: f64,
    /// Presence probability at the trough / the peak, each in [0, 1].
    pub min_available: f64,
    pub max_available: f64,
    /// Per-client phase offsets are uniform in [0, phase_jitter_h).
    pub phase_jitter_h: f64,
}

impl DiurnalAvailability {
    /// This client's deterministic phase offset, hours.
    fn phase_offset_h(&self, id: usize) -> f64 {
        hash01(self.seed, id as u64, 0xD1_0FF5E7) * self.phase_jitter_h
    }

    /// Presence probability for `id` at `clock_h` (before the slot draw).
    pub fn presence_prob(&self, id: usize, clock_h: f64) -> f64 {
        let phase = (clock_h + self.phase_offset_h(id) - self.peak_hour) / 24.0
            * std::f64::consts::TAU;
        self.min_available
            + (self.max_available - self.min_available) * 0.5 * (1.0 + phase.cos())
    }
}

impl AvailabilityModel for DiurnalAvailability {
    fn available(&self, id: usize, clock_h: f64) -> bool {
        let slot = (clock_h.max(0.0) / DIURNAL_SLOT_H).floor() as u64;
        hash01(self.seed, id as u64, slot.wrapping_mul(0x9E37_79B9).wrapping_add(0xA7))
            < self.presence_prob(id, clock_h)
    }
    fn next_change_h(&self, id: usize, clock_h: f64) -> Option<f64> {
        // Within a slot the draw is frozen, so availability can only
        // flip when the sine-wave probability crosses it. The slope of
        // the sine is bounded by amp·π/24 per hour, giving a sound
        // lower bound of gap/max_rate hours until the crossing; the
        // slot boundary (fresh draw) caps the bound either way.
        let clock_h = clock_h.max(0.0);
        let slot = (clock_h / DIURNAL_SLOT_H).floor();
        let slot_end = (slot + 1.0) * DIURNAL_SLOT_H;
        let amp = (self.max_available - self.min_available).abs();
        if amp == 0.0 {
            // Flat probability: only the per-slot draw can change.
            return Some(slot_end);
        }
        let draw = hash01(
            self.seed,
            id as u64,
            (slot as u64).wrapping_mul(0x9E37_79B9).wrapping_add(0xA7),
        );
        let max_rate = amp * 0.5 * std::f64::consts::TAU / 24.0;
        let gap_h = (self.presence_prob(id, clock_h) - draw).abs() / max_rate;
        Some(slot_end.min(clock_h + gap_h))
    }
    fn name(&self) -> &'static str {
        "diurnal"
    }
}

/// Trace-driven on/off churn: each client gets a periodic boolean
/// trace generated once from the seed by a two-state Markov chain, so
/// dwell times are coherent (a client that goes offline stays offline
/// for a while) instead of i.i.d. per round.
pub struct TraceAvailability {
    slot_h: f64,
    /// One period of on/off slots per client.
    traces: Vec<Vec<bool>>,
}

impl TraceAvailability {
    /// Generate `n` per-client traces covering `period_h` hours at
    /// `slot_h` resolution. `duty_cycle` is the stationary on-fraction;
    /// `churn` scales the per-slot switching pressure (0 = frozen at
    /// the initial state, 1 = maximal flipping at that duty cycle).
    pub fn generate(
        seed: u64,
        n: usize,
        period_h: f64,
        slot_h: f64,
        duty_cycle: f64,
        churn: f64,
    ) -> Self {
        let slots = (period_h / slot_h).ceil().max(1.0) as usize;
        let duty = duty_cycle.clamp(0.01, 0.99);
        // Stationary distribution of the chain is exactly `duty`:
        // P(off->on)/(P(off->on)+P(on->off)) = duty.
        let p_on_off = (churn * (1.0 - duty)).clamp(0.0, 1.0);
        let p_off_on = (churn * duty).clamp(0.0, 1.0);
        let traces = (0..n)
            .map(|id| {
                let mut rng = Rng::seed_from_u64(
                    seed ^ (id as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15).wrapping_add(0xA11),
                );
                let mut on = rng.gen_bool(duty);
                (0..slots)
                    .map(|_| {
                        let cur = on;
                        let flip_p = if on { p_on_off } else { p_off_on };
                        if rng.gen_bool(flip_p) {
                            on = !on;
                        }
                        cur
                    })
                    .collect()
            })
            .collect();
        Self { slot_h, traces }
    }

    /// Number of clients the traces were generated for.
    pub fn len(&self) -> usize {
        self.traces.len()
    }

    pub fn is_empty(&self) -> bool {
        self.traces.is_empty()
    }
}

impl AvailabilityModel for TraceAvailability {
    fn available(&self, id: usize, clock_h: f64) -> bool {
        if self.traces.is_empty() {
            return true;
        }
        let trace = &self.traces[id % self.traces.len()];
        let slot = (clock_h.max(0.0) / self.slot_h).floor() as u64 as usize % trace.len();
        trace[slot]
    }
    fn next_change_h(&self, id: usize, clock_h: f64) -> Option<f64> {
        // Exact: scan the periodic trace for the first future slot
        // whose state differs from the current one.
        if self.traces.is_empty() {
            return None; // degenerate always-on
        }
        let trace = &self.traces[id % self.traces.len()];
        let slot = (clock_h.max(0.0) / self.slot_h).floor() as u64;
        let cur = trace[slot as usize % trace.len()];
        for k in 1..=trace.len() as u64 {
            if trace[(slot + k) as usize % trace.len()] != cur {
                return Some((slot + k) as f64 * self.slot_h);
            }
        }
        None // constant trace: this client never flips
    }
    fn name(&self) -> &'static str {
        "trace"
    }
}

/// Wake-wheel bucket width, hours (3 simulated minutes). Coarse enough
/// that the BTreeMap stays small at 10M clients, fine enough that an
/// early-fired client is re-evaluated at most a handful of times before
/// its true change time.
const WAKE_BUCKET_WIDTH_H: f64 = 0.05;

/// Cached per-client availability driven by a time wheel: instead of
/// asking the model about all N clients every round, each client is
/// re-evaluated only when its model-declared
/// [`next_change_h`](AvailabilityModel::next_change_h) comes due.
///
/// Soundness: at registration time the cache holds `available(id, t₀)`
/// and the model guarantees no change before the registered wake time,
/// so the cache equals a direct model call at every clock the wheel has
/// been advanced to — the plan phase reading the cache is byte-
/// equivalent to the old per-client dynamic dispatch. The wheel may
/// fire a client *early* (bucket granularity, conservative bounds);
/// that costs a redundant re-evaluation, never a stale bit.
///
/// Per round this is O(due clients), not O(N): an `AlwaysOn` fleet
/// registers nothing (the coordinator skips the wheel entirely), a
/// trace fleet wakes only the clients whose slot actually flips, and a
/// diurnal fleet wakes the slice of clients whose draw sits near the
/// sine curve.
pub struct WakeWheel {
    avail: Vec<bool>,
    wheel: BucketWheel,
    /// Reusable pop buffer — no per-round allocation.
    fired: Vec<(u32, u32)>,
    /// Ids whose cached bit actually flipped during the most recent
    /// [`WakeWheel::advance`], ascending — the change list consumed by
    /// the registry's incremental eligible arena. A fired-but-unchanged
    /// client (early wake-up, conservative bound) is *not* listed:
    /// downstream consumers only care about real transitions.
    changed: Vec<u32>,
}

impl WakeWheel {
    /// Build the cache for `n` clients at `clock_h` — the one O(N)
    /// pass; every later [`WakeWheel::advance`] touches only due ids.
    pub fn new(model: &dyn AvailabilityModel, n: usize, clock_h: f64) -> Self {
        let mut w = Self {
            avail: vec![false; n],
            wheel: BucketWheel::new(WAKE_BUCKET_WIDTH_H),
            fired: Vec::new(),
            changed: Vec::new(),
        };
        for id in 0..n {
            w.refresh(model, id, clock_h);
        }
        w
    }

    /// Advance the cache to `clock_h` (monotone across calls):
    /// re-evaluate exactly the clients whose registered wake time is
    /// due, re-arming each at its next declared change.
    pub fn advance(&mut self, model: &dyn AvailabilityModel, clock_h: f64) {
        let mut fired = std::mem::take(&mut self.fired);
        fired.clear();
        self.changed.clear();
        self.wheel.pop_due(clock_h, &mut fired);
        for &(id, _) in &fired {
            let was = self.avail[id as usize];
            self.refresh(model, id as usize, clock_h);
            if self.avail[id as usize] != was {
                self.changed.push(id);
            }
        }
        self.changed.sort_unstable();
        self.fired = fired;
    }

    fn refresh(&mut self, model: &dyn AvailabilityModel, id: usize, clock_h: f64) {
        self.avail[id] = model.available(id, clock_h);
        if let Some(t) = model.next_change_h(id, clock_h) {
            // A bound at or before `now` (the conservative default, or
            // a crossing in progress) re-arms for the very next advance.
            self.wheel.insert(t.max(clock_h), id as u32, 0);
        }
    }

    /// The cached availability bits, valid for the clock last passed to
    /// [`WakeWheel::advance`] (or `new`). Indexed by client id.
    pub fn avail(&self) -> &[bool] {
        &self.avail
    }

    /// Ids whose availability bit flipped during the most recent
    /// [`WakeWheel::advance`], sorted ascending. Empty right after
    /// [`WakeWheel::new`] — the initial build is the baseline, not a
    /// transition.
    pub fn changed(&self) -> &[u32] {
        &self.changed
    }

    /// Clients currently armed for a future re-evaluation.
    pub fn pending(&self) -> usize {
        self.wheel.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn diurnal(min: f64, max: f64, jitter: f64) -> DiurnalAvailability {
        DiurnalAvailability {
            seed: 9,
            peak_hour: 20.0,
            min_available: min,
            max_available: max,
            phase_jitter_h: jitter,
        }
    }

    #[test]
    fn always_on_is_always_on() {
        assert!(AlwaysOn.available(0, 0.0));
        assert!(AlwaysOn.available(123, 1e6));
    }

    #[test]
    fn diurnal_prob_peaks_at_peak_hour_and_troughs_opposite() {
        let d = diurnal(0.1, 0.9, 0.0);
        assert!((d.presence_prob(7, 20.0) - 0.9).abs() < 1e-9);
        assert!((d.presence_prob(7, 8.0) - 0.1).abs() < 1e-9);
        // 24h-periodic.
        assert!((d.presence_prob(7, 20.0) - d.presence_prob(7, 44.0)).abs() < 1e-9);
    }

    #[test]
    fn diurnal_extremes_gate_everyone() {
        let none = diurnal(0.0, 0.0, 3.0);
        let all = diurnal(1.0, 1.0, 3.0);
        for id in 0..50 {
            for t in [0.0, 5.3, 12.0, 23.9, 100.7] {
                assert!(!none.available(id, t), "p=0 must never admit");
                assert!(all.available(id, t), "p=1 must always admit");
            }
        }
    }

    #[test]
    fn diurnal_is_deterministic_and_slot_coherent() {
        let d = diurnal(0.2, 0.8, 2.0);
        for id in 0..20 {
            for t in [0.0, 3.7, 11.1] {
                assert_eq!(d.available(id, t), d.available(id, t));
            }
        }
        // With a flat probability the draw depends only on the 0.25 h
        // slot: times inside one slot agree exactly.
        let flat = diurnal(0.5, 0.5, 2.0);
        for id in 0..20 {
            for t in [0.0, 3.7, 11.1] {
                assert_eq!(flat.available(id, t), flat.available(id, t + 0.01));
            }
        }
    }

    #[test]
    fn diurnal_population_tracks_probability() {
        let d = diurnal(0.05, 0.95, 0.0);
        let frac_at = |t: f64| {
            (0..1000).filter(|&id| d.available(id, t)).count() as f64 / 1000.0
        };
        let peak = frac_at(20.0);
        let trough = frac_at(8.0);
        assert!(peak > 0.8, "peak-hour presence {peak}");
        assert!(trough < 0.2, "trough presence {trough}");
    }

    #[test]
    fn trace_is_periodic_and_deterministic() {
        let t = TraceAvailability::generate(5, 30, 24.0, 0.5, 0.6, 0.2);
        assert_eq!(t.len(), 30);
        for id in 0..30 {
            for h in [0.0, 1.3, 13.7, 23.9] {
                assert_eq!(t.available(id, h), t.available(id, h));
                assert_eq!(t.available(id, h), t.available(id, h + 24.0), "periodic");
            }
        }
        let t2 = TraceAvailability::generate(5, 30, 24.0, 0.5, 0.6, 0.2);
        for id in 0..30 {
            assert_eq!(t.available(id, 7.25), t2.available(id, 7.25));
        }
    }

    #[test]
    fn always_on_never_changes() {
        assert_eq!(AlwaysOn.next_change_h(3, 7.0), None);
        let wheel = WakeWheel::new(&AlwaysOn, 100, 0.0);
        assert_eq!(wheel.pending(), 0, "always-on arms nothing");
        assert!(wheel.avail().iter().all(|&a| a));
    }

    #[test]
    fn diurnal_next_change_is_a_sound_lower_bound() {
        // The contract: availability is constant on [t, next). Sample
        // strictly inside the bound and demand agreement with t.
        for (min, max, jitter) in [(0.1, 0.9, 0.0), (0.2, 0.8, 3.0), (0.5, 0.5, 2.0)] {
            let d = diurnal(min, max, jitter);
            for id in 0..40 {
                for t in [0.0, 1.3, 7.77, 12.0, 19.9, 30.1] {
                    let next = d.next_change_h(id, t).expect("diurnal always re-arms");
                    assert!(next >= t, "bound must not precede now");
                    let state = d.available(id, t);
                    for f in [0.25, 0.5, 0.75, 0.999] {
                        let s = t + (next - t) * f;
                        assert_eq!(
                            d.available(id, s),
                            state,
                            "flip before declared bound: id={id} t={t} next={next} s={s}"
                        );
                    }
                }
            }
        }
    }

    #[test]
    fn trace_next_change_is_exact() {
        let t = TraceAvailability::generate(5, 30, 24.0, 0.5, 0.6, 0.2);
        let mut saw_change = false;
        for id in 0..30 {
            for h in [0.0, 1.3, 13.7, 23.9] {
                let state = t.available(id, h);
                match t.next_change_h(id, h) {
                    Some(next) => {
                        saw_change = true;
                        assert!(next > h, "trace changes land on future slot starts");
                        // Constant up to the declared change…
                        let mut s = h;
                        while s < next - 1e-9 {
                            assert_eq!(t.available(id, s), state);
                            s += 0.1;
                        }
                        // …and the change is real, not conservative.
                        assert_ne!(t.available(id, next + 1e-9), state);
                    }
                    None => {
                        // Constant trace: one full period agrees.
                        for k in 0..48 {
                            assert_eq!(t.available(id, h + k as f64 * 0.5), state);
                        }
                    }
                }
            }
        }
        assert!(saw_change, "churny traces must produce transitions");
    }

    #[test]
    fn wake_wheel_cache_matches_direct_model_calls() {
        let n = 200;
        // Uneven clock steps, including sub-slot ones, across both
        // dynamic models — the cache must agree with the model at every
        // advance point, bit for bit.
        let clocks =
            [0.0, 0.11, 0.25, 0.3, 1.0, 1.02, 2.75, 5.5, 12.0, 12.26, 23.9, 24.1, 30.0];
        let models: [Box<dyn AvailabilityModel>; 3] = [
            Box::new(diurnal(0.1, 0.9, 2.0)),
            Box::new(diurnal(0.4, 0.4, 1.0)),
            Box::new(TraceAvailability::generate(5, n, 24.0, 0.5, 0.6, 0.2)),
        ];
        for model in &models {
            let mut wheel = WakeWheel::new(model.as_ref(), n, clocks[0]);
            for &clock in &clocks {
                wheel.advance(model.as_ref(), clock);
                for id in 0..n {
                    assert_eq!(
                        wheel.avail()[id],
                        model.available(id, clock),
                        "stale cache: model={} id={id} clock={clock}",
                        model.name()
                    );
                }
            }
        }
    }

    #[test]
    fn wake_wheel_change_list_is_exactly_the_bit_diff() {
        let n = 200;
        let clocks =
            [0.0, 0.11, 0.25, 0.3, 1.0, 1.02, 2.75, 5.5, 12.0, 12.26, 23.9, 24.1, 30.0];
        let models: [Box<dyn AvailabilityModel>; 2] = [
            Box::new(diurnal(0.1, 0.9, 2.0)),
            Box::new(TraceAvailability::generate(5, n, 24.0, 0.5, 0.6, 0.2)),
        ];
        let mut saw_changes = false;
        for model in &models {
            let mut wheel = WakeWheel::new(model.as_ref(), n, clocks[0]);
            assert!(wheel.changed().is_empty(), "initial build reports no transitions");
            let mut prev: Vec<bool> = wheel.avail().to_vec();
            for &clock in &clocks[1..] {
                wheel.advance(model.as_ref(), clock);
                let expected: Vec<u32> = (0..n)
                    .filter(|&id| wheel.avail()[id] != prev[id])
                    .map(|id| id as u32)
                    .collect();
                assert_eq!(
                    wheel.changed(),
                    expected.as_slice(),
                    "change list must equal the bitmap diff: model={} clock={clock}",
                    model.name()
                );
                saw_changes |= !expected.is_empty();
                prev = wheel.avail().to_vec();
            }
        }
        assert!(saw_changes, "dynamic models must produce some flips");
    }

    #[test]
    fn trace_duty_cycle_holds_on_average() {
        let t = TraceAvailability::generate(11, 200, 24.0, 0.5, 0.6, 0.15);
        let mut on = 0usize;
        let mut total = 0usize;
        for id in 0..200 {
            for slot in 0..48 {
                total += 1;
                if t.available(id, slot as f64 * 0.5) {
                    on += 1;
                }
            }
        }
        let frac = on as f64 / total as f64;
        assert!((frac - 0.6).abs() < 0.08, "duty cycle drifted: {frac}");
    }
}
