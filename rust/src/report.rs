//! Campaign report emission and the byte-stable shard merge.
//!
//! A campaign's merged artifacts (`<name>.campaign.json` / `.csv`) used
//! to be written inline by `campaign::run_campaign`; sharded campaigns
//! (`eafl sweep --shard I/N`) need the same emission *after the fact*,
//! over per-run files produced by several processes — possibly in
//! several output directories. This module is that seam:
//!
//!  - [`CampaignReport`] / [`CampaignRun`] — the merged result and its
//!    JSON/CSV encodings (moved here from `campaign`, which re-exports
//!    them);
//!  - [`Manifest`] — the full grid in expansion order, written as
//!    `<name>.manifest.json` by every sweep that has an output
//!    directory. All shards of one campaign derive the manifest from
//!    the same grid, so they write byte-identical files and need no
//!    coordination;
//!  - [`merge_dirs`] / [`merge_with_detail`] — the order-stable merge:
//!    cells are emitted in *manifest* order (= single-process grid
//!    order), never in shard or completion order, and each cell's
//!    `<name>.config.toml` fingerprint must hash to the manifest's
//!    recorded value. Summaries round-trip through JSON bit-exactly
//!    (see `metrics::Summary`), so a shard-then-merge campaign
//!    reproduces a single-process `eafl sweep` byte for byte — the
//!    contract `rust/tests/campaign_sharding.rs` pins across real
//!    processes.
//!  - [`quarantine`] — the corruption policy shared by the merge, the
//!    sweep resume and `eafl trace summarize`: a torn, truncated or
//!    fingerprint-mismatched artifact is *moved aside* to
//!    `<file>.quarantine` (named on stderr), never panicked over and
//!    never silently skipped. The merge reports **all** invalid or
//!    missing cells in one pass, each with its reason, so a multi-host
//!    operator gets one actionable error instead of a whack-a-mole.

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

use anyhow::{bail, ensure, Context, Result};

use crate::config::SelectorKind;
use crate::metrics::Summary;
use crate::util::json::Json;

/// Manifest schema tag (bumped on incompatible layout changes).
pub const MANIFEST_SCHEMA: &str = "eafl-campaign-manifest-v1";

/// FNV-1a 64-bit — the stable hash behind both the shard partition
/// (`campaign::shard_of`) and the manifest's config fingerprints. Tiny,
/// dependency-free, and fully specified, so any process (or language)
/// can recompute the partition.
pub fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

/// One finished run: its grid coordinates plus the end-of-run summary.
#[derive(Debug, Clone)]
pub struct CampaignRun {
    pub selector: SelectorKind,
    pub scenario: String,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    /// Campaign energy budget in joules (0 = unlimited) — pairs with
    /// the summary's `total_fl_energy_j` to plot the energy/accuracy
    /// frontier.
    pub budget_j: f64,
    pub summary: Summary,
}

/// The merged campaign result, in grid order.
#[derive(Debug, Clone)]
pub struct CampaignReport {
    pub name: String,
    pub runs: Vec<CampaignRun>,
}

impl CampaignReport {
    /// Merged summary as JSON (in-tree codec; offline build, no serde).
    pub fn to_json(&self) -> Json {
        let runs: Vec<Json> = self
            .runs
            .iter()
            .map(|r| {
                let mut m = BTreeMap::new();
                m.insert("selector".to_string(), Json::Str(r.selector.to_string()));
                m.insert("scenario".to_string(), Json::Str(r.scenario.clone()));
                m.insert("seed".to_string(), Json::Num(r.seed as f64));
                m.insert("f".to_string(), Json::Num(r.f));
                m.insert("clients".to_string(), Json::Num(r.clients as f64));
                m.insert("budget_j".to_string(), Json::Num(r.budget_j));
                m.insert("summary".to_string(), r.summary.to_json());
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("campaign".to_string(), Json::Str(self.name.clone()));
        top.insert("total_runs".to_string(), Json::Num(self.runs.len() as f64));
        top.insert("runs".to_string(), Json::Arr(runs));
        Json::Obj(top)
    }

    /// One CSV row per run (the merged table the plots consume). The
    /// energy/accuracy frontier reads three of these columns per row:
    /// `budget_j` (the cap, 0 = unlimited), `energy_spent_j` (what the
    /// ledger actually reconciled — the summary's FL energy total) and
    /// `final_accuracy`.
    pub fn to_csv(&self) -> String {
        let mut out = String::from(
            "selector,scenario,seed,f,clients,budget_j,rounds,committed_rounds,\
             final_accuracy,best_accuracy,final_fairness,total_dropouts,\
             mean_round_duration_s,wall_clock_h,total_fl_energy_j,energy_spent_j\n",
        );
        for r in &self.runs {
            let s = &r.summary;
            out.push_str(&format!(
                "{},{},{},{},{},{},{},{},{:.6},{:.6},{:.6},{},{:.3},{:.6},{:.3},{:.3}\n",
                r.selector,
                r.scenario,
                r.seed,
                r.f,
                r.clients,
                r.budget_j,
                s.rounds,
                s.committed_rounds,
                s.final_accuracy,
                s.best_accuracy,
                s.final_fairness,
                s.total_dropouts,
                s.mean_round_duration_s,
                s.wall_clock_h,
                s.total_fl_energy_j,
                s.total_fl_energy_j,
            ));
        }
        out
    }

    /// Mean final accuracy per selector (quick cross-seed aggregate).
    pub fn mean_accuracy_by_selector(&self) -> Vec<(SelectorKind, f64)> {
        let mut acc: Vec<(SelectorKind, f64, usize)> = Vec::new();
        for r in &self.runs {
            match acc.iter_mut().find(|(k, _, _)| *k == r.selector) {
                Some(slot) => {
                    slot.1 += r.summary.final_accuracy;
                    slot.2 += 1;
                }
                None => acc.push((r.selector, r.summary.final_accuracy, 1)),
            }
        }
        acc.into_iter().map(|(k, sum, n)| (k, sum / n as f64)).collect()
    }

    /// Total drop-outs per (scenario, selector) — the environment-
    /// differentiation signal (does `diurnal` kill a different number
    /// of clients than `steady` under the same seeds?).
    pub fn dropouts_by_scenario(&self) -> Vec<(String, SelectorKind, usize)> {
        let mut acc: Vec<(String, SelectorKind, usize)> = Vec::new();
        for r in &self.runs {
            match acc
                .iter_mut()
                .find(|(s, k, _)| *s == r.scenario && *k == r.selector)
            {
                Some(slot) => slot.2 += r.summary.total_dropouts,
                None => acc.push((r.scenario.clone(), r.selector, r.summary.total_dropouts)),
            }
        }
        acc
    }
}

/// Write the merged `<name>.campaign.json` / `<name>.campaign.csv` into
/// `dir`. The one emission path for single-process sweeps, shard merges
/// and `eafl merge` — byte-stability of the merge reduces to "same
/// [`CampaignReport`] in, same bytes out".
pub fn write_report(dir: &Path, report: &CampaignReport) -> Result<(PathBuf, PathBuf)> {
    let json_path = dir.join(format!("{}.campaign.json", report.name));
    crate::fault::write_artifact(
        crate::fault::ArtifactKind::Campaign,
        None,
        &json_path,
        &report.to_json().to_string_pretty(),
    )?;
    let csv_path = dir.join(format!("{}.campaign.csv", report.name));
    crate::fault::write_artifact(crate::fault::ArtifactKind::Campaign, None, &csv_path, &report.to_csv())?;
    Ok((json_path, csv_path))
}

/// Move a torn/corrupt/mismatched artifact aside to `<file>.quarantine`
/// and say so on stderr. Never panics and never deletes: the evidence
/// survives for post-mortems while readers stop tripping over it (a
/// rename also beats deletion for crash-consistency — it is atomic on
/// the same filesystem). Returns the quarantine path, or `None` when
/// the move itself failed (also reported, never silent).
pub fn quarantine(path: &Path, reason: &str) -> Option<PathBuf> {
    let mut name = path.file_name().map(|n| n.to_os_string()).unwrap_or_default();
    name.push(".quarantine");
    let dest = path.with_file_name(name);
    match std::fs::rename(path, &dest) {
        Ok(()) => {
            eprintln!("[quarantine] {}: {reason} — moved to {}", path.display(), dest.display());
            Some(dest)
        }
        Err(e) => {
            eprintln!(
                "[quarantine] {}: {reason} — could not move aside ({e}); leaving in place",
                path.display()
            );
            None
        }
    }
}

/// One grid cell's identity inside a [`Manifest`]: the coordinates that
/// name it plus the FNV-1a hash of its resolved config fingerprint
/// (the `<name>.config.toml` contents a finished run leaves behind).
#[derive(Debug, Clone, PartialEq)]
pub struct CellMeta {
    pub name: String,
    pub selector: SelectorKind,
    pub scenario: String,
    pub seed: u64,
    pub f: f64,
    pub clients: usize,
    /// Campaign energy budget in joules (0 = unlimited). Decoded
    /// leniently — manifests written before the budget axis existed
    /// simply omit the key — so the schema tag stays at v1.
    pub budget_j: f64,
    /// `fnv1a64` of the cell's config fingerprint text, hex-encoded in
    /// JSON (u64 does not survive an f64 JSON number).
    pub fingerprint_fnv: u64,
}

/// The full expanded grid of one campaign, in expansion order — the
/// merge's ordering and completeness authority. Every shard derives it
/// from the same grid, so all shards of one campaign write identical
/// `<name>.manifest.json` bytes.
#[derive(Debug, Clone, PartialEq)]
pub struct Manifest {
    pub campaign: String,
    pub cells: Vec<CellMeta>,
}

impl Manifest {
    pub fn to_json(&self) -> Json {
        let cells: Vec<Json> = self
            .cells
            .iter()
            .map(|c| {
                let mut m = BTreeMap::new();
                m.insert("name".to_string(), Json::Str(c.name.clone()));
                m.insert("selector".to_string(), Json::Str(c.selector.to_string()));
                m.insert("scenario".to_string(), Json::Str(c.scenario.clone()));
                // Decimal string, not a JSON number: a u64 seed above
                // 2^53 would round through f64 and break the merged
                // report's byte-identity with a single-process sweep.
                m.insert("seed".to_string(), Json::Str(c.seed.to_string()));
                m.insert("f".to_string(), Json::Num(c.f));
                m.insert("clients".to_string(), Json::Num(c.clients as f64));
                m.insert("budget_j".to_string(), Json::Num(c.budget_j));
                m.insert(
                    "fingerprint_fnv".to_string(),
                    Json::Str(format!("{:016x}", c.fingerprint_fnv)),
                );
                Json::Obj(m)
            })
            .collect();
        let mut top = BTreeMap::new();
        top.insert("schema".to_string(), Json::Str(MANIFEST_SCHEMA.to_string()));
        top.insert("campaign".to_string(), Json::Str(self.campaign.clone()));
        top.insert("total_cells".to_string(), Json::Num(self.cells.len() as f64));
        top.insert("cells".to_string(), Json::Arr(cells));
        Json::Obj(top)
    }

    pub fn from_json(j: &Json) -> Result<Self> {
        let schema = j.field("schema")?.as_str().unwrap_or("");
        ensure!(
            schema == MANIFEST_SCHEMA,
            "unsupported manifest schema {schema:?} (expected {MANIFEST_SCHEMA})"
        );
        let campaign = j
            .field("campaign")?
            .as_str()
            .context("manifest campaign is not a string")?
            .to_string();
        let mut cells = Vec::new();
        for c in j.field("cells")?.as_arr().context("manifest cells is not an array")? {
            let str_field = |key: &str| -> Result<String> {
                Ok(c.field(key)?
                    .as_str()
                    .with_context(|| format!("manifest cell field {key:?} is not a string"))?
                    .to_string())
            };
            let num_field = |key: &str| -> Result<f64> {
                c.field(key)?
                    .as_f64()
                    .with_context(|| format!("manifest cell field {key:?} is not a number"))
            };
            cells.push(CellMeta {
                name: str_field("name")?,
                selector: str_field("selector")?.parse()?,
                scenario: str_field("scenario")?,
                seed: str_field("seed")?
                    .parse()
                    .context("manifest cell seed is not a u64")?,
                f: num_field("f")?,
                clients: num_field("clients")? as usize,
                // Lenient: pre-budget manifests have no budget_j key;
                // they describe unlimited-energy campaigns.
                budget_j: if c.get("budget_j").is_some() { num_field("budget_j")? } else { 0.0 },
                fingerprint_fnv: u64::from_str_radix(&str_field("fingerprint_fnv")?, 16)
                    .context("manifest fingerprint_fnv is not hex")?,
            });
        }
        Ok(Self { campaign, cells })
    }

    /// The manifest's path inside an output directory.
    pub fn path_in(&self, dir: &Path) -> PathBuf {
        dir.join(format!("{}.manifest.json", self.campaign))
    }

    /// Write `<campaign>.manifest.json` into `dir`, atomically (write
    /// to a temp file, then rename) so concurrent shards never expose a
    /// torn manifest. Identical content is left untouched; different
    /// content (the grid changed since a previous sweep into this
    /// directory) is overwritten with a warning — per-cell fingerprints
    /// keep stale summaries from leaking into the new campaign.
    pub fn write(&self, dir: &Path) -> Result<PathBuf> {
        let path = self.path_in(dir);
        let text = self.to_json().to_string_pretty();
        if let Ok(existing) = std::fs::read_to_string(&path) {
            if existing == text {
                return Ok(path);
            }
            eprintln!(
                "[campaign] grid changed: overwriting stale manifest {}",
                path.display()
            );
        }
        let tmp = dir.join(format!(
            ".{}.manifest.{}.tmp",
            self.campaign,
            std::process::id()
        ));
        crate::fault::write_artifact(crate::fault::ArtifactKind::Manifest, None, &tmp, &text)
            .with_context(|| format!("writing {tmp:?}"))?;
        std::fs::rename(&tmp, &path)
            .with_context(|| format!("renaming {tmp:?} -> {path:?}"))?;
        Ok(path)
    }
}

/// Locate the single `*.manifest.json` in `dir`; returns its path and
/// raw bytes (the merge compares manifests byte-for-byte across dirs,
/// and `eafl merge --out` copies them into the merged directory).
/// `Ok(None)` means the directory simply has no manifest; more than
/// one is a user error (two campaigns swept into one directory).
pub fn find_manifest(dir: &Path) -> Result<Option<(PathBuf, String)>> {
    let mut found: Vec<PathBuf> = Vec::new();
    let entries =
        std::fs::read_dir(dir).with_context(|| format!("reading directory {dir:?}"))?;
    for entry in entries {
        let path = entry?.path();
        if path
            .file_name()
            .and_then(|n| n.to_str())
            .map_or(false, |n| n.ends_with(".manifest.json") && !n.starts_with('.'))
        {
            found.push(path);
        }
    }
    found.sort();
    match found.as_slice() {
        [] => Ok(None),
        [one] => {
            let text = std::fs::read_to_string(one)
                .with_context(|| format!("reading manifest {one:?}"))?;
            Ok(Some((one.clone(), text)))
        }
        many => bail!(
            "multiple campaign manifests in {}: {} — merge one campaign at a time",
            dir.display(),
            many.iter()
                .map(|p| p.display().to_string())
                .collect::<Vec<_>>()
                .join(", ")
        ),
    }
}

/// What one directory holds for one grid cell.
enum LoadOutcome {
    /// Valid: fingerprint matches the manifest and the summary parses.
    Loaded(Summary),
    /// Neither artifact present — the cell never ran here.
    Missing,
    /// Present but unusable; the reason says why, and the offending
    /// files have been quarantined where that is sound.
    Invalid(String),
}

/// Load one cell from `dir`, classifying (and quarantining) instead of
/// silently skipping: the difference between "not here" and "here but
/// torn/stale" is exactly what a multi-host operator needs to know.
fn load_cell(dir: &Path, cell: &CellMeta) -> LoadOutcome {
    let cfg_path = dir.join(format!("{}.config.toml", cell.name));
    let sum_path = dir.join(format!("{}.summary.json", cell.name));
    let cfg = std::fs::read_to_string(&cfg_path).ok();
    let sum = std::fs::read_to_string(&sum_path).ok();
    match (cfg, sum) {
        (None, None) => LoadOutcome::Missing,
        // The fingerprint is written after the summary, so a summary
        // alone is a cell whose writer died between the two files — it
        // cannot be verified against the manifest.
        (None, Some(_)) => {
            quarantine(&sum_path, "summary without its config fingerprint (torn cell?)");
            LoadOutcome::Invalid("summary present but unverifiable (no config fingerprint) — quarantined".into())
        }
        // A fingerprint alone shouldn't happen given the write order;
        // the config may well be valid, so leave it (a recompute
        // overwrites both files anyway).
        (Some(_), None) => {
            LoadOutcome::Invalid("config fingerprint present but summary.json missing".into())
        }
        (Some(cfg), Some(sum)) => {
            if fnv1a64(cfg.as_bytes()) != cell.fingerprint_fnv {
                quarantine(&cfg_path, "config fingerprint mismatch vs manifest (torn write, bit rot, or a stale campaign)");
                quarantine(&sum_path, "summary of a fingerprint-mismatched cell");
                return LoadOutcome::Invalid(
                    "config fingerprint mismatch vs manifest — quarantined".into(),
                );
            }
            match Json::parse(&sum).and_then(|j| Summary::from_json(&j)) {
                Ok(summary) => LoadOutcome::Loaded(summary),
                Err(_) => {
                    quarantine(&sum_path, "torn/unparseable summary.json");
                    LoadOutcome::Invalid("torn/unparseable summary.json — quarantined".into())
                }
            }
        }
    }
}

/// One unusable grid cell in a [`MergeDetail::Incomplete`] result.
#[derive(Debug, Clone)]
pub struct CellProblem {
    pub cell: String,
    /// Per-directory reasons, `; `-joined ("missing" when no directory
    /// has any trace of the cell).
    pub reason: String,
}

/// The merge's full verdict — what a supervisor retry loop needs
/// (which cells, hence which shards, to rerun), beyond `merge_dirs`'s
/// flattened error string.
pub enum MergeDetail {
    /// Every grid cell merged; the manifest text rides along so
    /// callers can copy it without re-scanning directories.
    Complete { report: CampaignReport, manifest_text: String },
    /// No directory holds a (valid) manifest; `quarantined` counts the
    /// unparseable ones moved aside during the scan.
    NoManifest { quarantined: usize },
    /// Some cells are missing or invalid — all of them, with reasons.
    Incomplete { problems: Vec<CellProblem>, total: usize },
}

/// The order-stable merge: combine per-run artifacts from one or more
/// sweep output directories into the full [`CampaignReport`].
///
/// Rules (the shard/merge protocol, see the crate docs):
///  1. every directory holding a *valid* manifest must hold the
///     byte-identical one — shards of the same campaign always do;
///     parseable-but-different manifests are a user error, while a
///     torn/unparseable manifest is quarantined and the directory
///     treated as manifest-less;
///  2. cells are emitted in manifest order (= grid expansion order),
///     regardless of which shard ran them, in which directory they
///     landed, or when they finished;
///  3. a cell counts only if its summary parses and its config
///     fingerprint hashes to the manifest's value; directories are
///     searched in argument order and the first valid copy wins (all
///     copies are bit-identical by the determinism contract anyway).
///     Torn or mismatched artifacts are quarantined on sight;
///  4. the verdict covers *every* problem cell in one pass with its
///     reason — never just the first — so one rerun-and-merge fixes
///     everything at once.
pub fn merge_with_detail(dirs: &[PathBuf]) -> Result<MergeDetail> {
    ensure!(!dirs.is_empty(), "merge needs at least one directory");
    let mut first: Option<(PathBuf, String)> = None;
    let mut quarantined = 0usize;
    for dir in dirs {
        let Some((path, text)) = find_manifest(dir)? else { continue };
        if Json::parse(&text).and_then(|j| Manifest::from_json(&j)).is_err() {
            quarantine(&path, "torn/unparseable campaign manifest");
            quarantined += 1;
            continue;
        }
        match &first {
            None => first = Some((path, text)),
            Some((first_path, first_text)) => ensure!(
                text == *first_text,
                "campaign manifests disagree: {} vs {} — these directories hold \
                 different campaigns (or different grids of one campaign)",
                first_path.display(),
                path.display()
            ),
        }
    }
    let Some((first_path, manifest_text)) = first else {
        return Ok(MergeDetail::NoManifest { quarantined });
    };
    let manifest = Manifest::from_json(
        &Json::parse(&manifest_text)
            .with_context(|| format!("parsing manifest {first_path:?}"))?,
    )?;

    let mut runs = Vec::with_capacity(manifest.cells.len());
    let mut problems: Vec<CellProblem> = Vec::new();
    for cell in &manifest.cells {
        let mut found = None;
        let mut reasons: Vec<String> = Vec::new();
        for dir in dirs {
            match load_cell(dir, cell) {
                LoadOutcome::Loaded(summary) => {
                    found = Some(summary);
                    break;
                }
                LoadOutcome::Missing => {}
                LoadOutcome::Invalid(reason) => reasons.push(if dirs.len() > 1 {
                    format!("{}: {reason}", dir.display())
                } else {
                    reason
                }),
            }
        }
        match found {
            Some(summary) => runs.push(CampaignRun {
                selector: cell.selector,
                scenario: cell.scenario.clone(),
                seed: cell.seed,
                f: cell.f,
                clients: cell.clients,
                budget_j: cell.budget_j,
                summary,
            }),
            None => problems.push(CellProblem {
                cell: cell.name.clone(),
                reason: if reasons.is_empty() {
                    "no finished summary in any directory".into()
                } else {
                    reasons.join("; ")
                },
            }),
        }
    }
    if !problems.is_empty() {
        return Ok(MergeDetail::Incomplete { problems, total: manifest.cells.len() });
    }
    Ok(MergeDetail::Complete {
        report: CampaignReport { name: manifest.campaign, runs },
        manifest_text,
    })
}

/// Render a [`MergeDetail::NoManifest`] as the user-facing error.
pub fn no_manifest_error(dirs: &[PathBuf], quarantined: usize) -> anyhow::Error {
    let where_ = dirs.iter().map(|d| d.display().to_string()).collect::<Vec<_>>().join(", ");
    let note = if quarantined > 0 {
        format!(" ({quarantined} torn manifest(s) quarantined — rerun the sweep to regenerate)")
    } else {
        " — was this directory produced by `eafl sweep`?".to_string()
    };
    anyhow::anyhow!("no campaign manifest (*.manifest.json) in {where_}{note}")
}

/// Render a [`MergeDetail::Incomplete`] as the user-facing error:
/// every problem cell with its reason (capped for sanity), plus the
/// remedy.
pub fn incomplete_error(problems: &[CellProblem], total: usize) -> anyhow::Error {
    let shown = problems
        .iter()
        .take(12)
        .map(|p| format!("\n  {} — {}", p.cell, p.reason))
        .collect::<Vec<_>>()
        .join("");
    let more = problems.len().saturating_sub(12);
    let suffix = if more > 0 { format!("\n  (+{more} more)") } else { String::new() };
    anyhow::anyhow!(
        "merge incomplete: {}/{total} grid cells have no finished summary:{shown}{suffix}\n\
         — rerun the owning shards into the same --out (resume skips finished \
         cells), then merge again",
        problems.len()
    )
}

/// [`merge_with_detail`] flattened to the classic all-or-error shape.
pub fn merge_dirs(dirs: &[PathBuf]) -> Result<CampaignReport> {
    match merge_with_detail(dirs)? {
        MergeDetail::Complete { report, .. } => Ok(report),
        MergeDetail::NoManifest { quarantined } => Err(no_manifest_error(dirs, quarantined)),
        MergeDetail::Incomplete { problems, total } => Err(incomplete_error(&problems, total)),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::metrics::MetricsLog;

    fn run(scenario: &str, selector: SelectorKind, dropouts: usize) -> CampaignRun {
        let mut summary = MetricsLog::new("x").summary();
        summary.total_dropouts = dropouts;
        CampaignRun {
            selector,
            scenario: scenario.into(),
            seed: 1,
            f: 0.25,
            clients: 10,
            budget_j: 0.0,
            summary,
        }
    }

    #[test]
    fn fnv1a64_matches_reference_vectors() {
        // Published FNV-1a test vectors — the partition must never
        // silently change across refactors.
        assert_eq!(fnv1a64(b""), 0xcbf2_9ce4_8422_2325);
        assert_eq!(fnv1a64(b"a"), 0xaf63_dc4c_8601_ec8c);
        assert_eq!(fnv1a64(b"foobar"), 0x85944171f73967e8);
        assert_ne!(fnv1a64(b"cell-1"), fnv1a64(b"cell-2"));
    }

    #[test]
    fn report_csv_has_one_row_per_run_plus_header() {
        let report = CampaignReport {
            name: "t".into(),
            runs: vec![run("steady", SelectorKind::Eafl, 0)],
        };
        let csv = report.to_csv();
        assert_eq!(csv.lines().count(), 2);
        assert!(csv.starts_with("selector,scenario,seed,f,clients,budget_j,"));
        // The frontier columns ride in every report.
        let header = csv.lines().next().unwrap();
        for col in ["budget_j", "energy_spent_j", "final_accuracy"] {
            assert!(header.split(',').any(|c| c == col), "missing column {col}: {header}");
        }
        assert!(header.ends_with(",energy_spent_j"));
        assert!(csv.lines().nth(1).unwrap().starts_with("eafl,steady,1,"));
        let parsed = Json::parse(&report.to_json().to_string_pretty()).unwrap();
        assert_eq!(parsed.field("total_runs").unwrap().as_usize(), Some(1));
        let run0 = &parsed.field("runs").unwrap().as_arr().unwrap()[0];
        assert_eq!(run0.field("scenario").unwrap().as_str(), Some("steady"));
        assert_eq!(run0.field("budget_j").unwrap().as_f64(), Some(0.0));
    }

    #[test]
    fn dropouts_by_scenario_groups_cells() {
        let report = CampaignReport {
            name: "t".into(),
            runs: vec![
                run("steady", SelectorKind::Eafl, 3),
                run("steady", SelectorKind::Eafl, 4),
                run("diurnal", SelectorKind::Eafl, 9),
            ],
        };
        let groups = report.dropouts_by_scenario();
        assert_eq!(groups.len(), 2);
        assert_eq!(groups[0], ("steady".to_string(), SelectorKind::Eafl, 7));
        assert_eq!(groups[1], ("diurnal".to_string(), SelectorKind::Eafl, 9));
    }

    fn manifest() -> Manifest {
        Manifest {
            campaign: "m".into(),
            cells: vec![CellMeta {
                name: "m-eafl-steady-n10-f0.25-s1".into(),
                selector: SelectorKind::Eafl,
                scenario: "steady".into(),
                seed: 1,
                f: 0.25,
                clients: 10,
                budget_j: 0.0,
                fingerprint_fnv: fnv1a64(b"cfg"),
            }],
        }
    }

    #[test]
    fn manifest_roundtrips_through_json() {
        let mut m = manifest();
        // Seeds are arbitrary u64s; above 2^53 they no longer fit an
        // f64 JSON number exactly, which is why the manifest encodes
        // them as decimal strings.
        m.cells[0].seed = u64::MAX - 1;
        let back = Manifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap())
            .unwrap();
        assert_eq!(back, m);
        assert_eq!(back.cells[0].seed, u64::MAX - 1);
    }

    #[test]
    fn manifest_budget_roundtrips_and_pre_budget_manifests_still_parse() {
        let mut m = manifest();
        m.cells[0].budget_j = 2500.0;
        let back =
            Manifest::from_json(&Json::parse(&m.to_json().to_string_pretty()).unwrap()).unwrap();
        assert_eq!(back.cells[0].budget_j, 2500.0);
        // A manifest written before the budget axis existed has no
        // budget_j key: it must decode as an unlimited-energy cell
        // under the unchanged v1 schema tag.
        let mut j = manifest().to_json();
        if let Json::Obj(top) = &mut j {
            if let Some(Json::Arr(cells)) = top.get_mut("cells") {
                if let Json::Obj(cell) = &mut cells[0] {
                    cell.remove("budget_j");
                }
            }
        }
        let old = Manifest::from_json(&j).unwrap();
        assert_eq!(old.cells[0].budget_j, 0.0);
    }

    #[test]
    fn manifest_rejects_wrong_schema() {
        let mut j = manifest().to_json();
        if let Json::Obj(m) = &mut j {
            m.insert("schema".into(), Json::Str("bogus".into()));
        }
        assert!(Manifest::from_json(&j).is_err());
    }

    #[test]
    fn manifest_write_is_idempotent_and_detects_grid_changes() {
        let dir = std::env::temp_dir().join(format!("eafl-manifest-{}", std::process::id()));
        std::fs::create_dir_all(&dir).unwrap();
        let m = manifest();
        let path = m.write(&dir).unwrap();
        let bytes = std::fs::read_to_string(&path).unwrap();
        // Re-writing the same manifest leaves the bytes untouched.
        m.write(&dir).unwrap();
        assert_eq!(std::fs::read_to_string(&path).unwrap(), bytes);
        // A changed grid overwrites (with a stderr warning).
        let mut m2 = m.clone();
        m2.cells[0].seed = 2;
        m2.write(&dir).unwrap();
        assert_ne!(std::fs::read_to_string(&path).unwrap(), bytes);
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_requires_manifest_and_complete_cells() {
        let dir = std::env::temp_dir().join(format!("eafl-merge-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        // No manifest at all.
        let err = merge_dirs(&[dir.clone()]).unwrap_err().to_string();
        assert!(err.contains("manifest"), "{err}");

        // Manifest but no cell artifacts: the missing cell is named.
        let m = manifest();
        m.write(&dir).unwrap();
        let err = merge_dirs(&[dir.clone()]).unwrap_err().to_string();
        assert!(err.contains("m-eafl-steady-n10-f0.25-s1"), "{err}");

        // Cell artifacts with the right fingerprint merge cleanly.
        let summary = MetricsLog::new("m-eafl-steady-n10-f0.25-s1").summary();
        std::fs::write(
            dir.join("m-eafl-steady-n10-f0.25-s1.summary.json"),
            summary.to_json().to_string_pretty(),
        )
        .unwrap();
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s1.config.toml"), "cfg").unwrap();
        let report = merge_dirs(&[dir.clone()]).unwrap();
        assert_eq!(report.name, "m");
        assert_eq!(report.runs.len(), 1);
        assert_eq!(report.runs[0].scenario, "steady");

        // A wrong fingerprint makes the cell invisible again.
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s1.config.toml"), "other").unwrap();
        assert!(merge_dirs(&[dir.clone()]).is_err());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_reports_every_problem_cell_with_reasons_and_quarantines() {
        let dir = std::env::temp_dir().join(format!("eafl-mergeall-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let mut m = manifest();
        let torn = CellMeta { name: "m-eafl-steady-n10-f0.25-s2".into(), seed: 2, ..m.cells[0].clone() };
        let stale = CellMeta { name: "m-eafl-steady-n10-f0.25-s3".into(), seed: 3, ..m.cells[0].clone() };
        m.cells.push(torn);
        m.cells.push(stale);
        m.write(&dir).unwrap();
        // Cell s1: never ran. Cell s2: torn summary (half-written
        // JSON). Cell s3: fingerprint mismatch (stale campaign).
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s2.config.toml"), "cfg").unwrap();
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s2.summary.json"), "{\"ro").unwrap();
        std::fs::write(dir.join("m-eafl-steady-n10-f0.25-s3.config.toml"), "stale").unwrap();
        let summary = MetricsLog::new("m-eafl-steady-n10-f0.25-s3").summary();
        std::fs::write(
            dir.join("m-eafl-steady-n10-f0.25-s3.summary.json"),
            summary.to_json().to_string_pretty(),
        )
        .unwrap();

        // One pass reports all three cells, each with its own reason.
        let MergeDetail::Incomplete { problems, total } =
            merge_with_detail(&[dir.clone()]).unwrap()
        else {
            panic!("expected Incomplete")
        };
        assert_eq!(total, 3);
        assert_eq!(problems.len(), 3);
        assert!(problems[0].reason.contains("no finished summary"), "{}", problems[0].reason);
        assert!(problems[1].reason.contains("unparseable"), "{}", problems[1].reason);
        assert!(problems[2].reason.contains("fingerprint mismatch"), "{}", problems[2].reason);

        // The torn/stale artifacts were moved aside, not deleted.
        assert!(dir.join("m-eafl-steady-n10-f0.25-s2.summary.json.quarantine").exists());
        assert!(dir.join("m-eafl-steady-n10-f0.25-s3.config.toml.quarantine").exists());
        assert!(dir.join("m-eafl-steady-n10-f0.25-s3.summary.json.quarantine").exists());
        assert!(!dir.join("m-eafl-steady-n10-f0.25-s2.summary.json").exists());

        // The flattened error names every cell.
        let err = incomplete_error(&problems, total).to_string();
        assert!(err.starts_with("merge incomplete: 3/3"), "{err}");
        for cell in ["s1", "s2", "s3"] {
            assert!(err.contains(&format!("m-eafl-steady-n10-f0.25-{cell}")), "{err}");
        }
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_quarantines_torn_manifest_and_reports_no_manifest() {
        let dir = std::env::temp_dir().join(format!("eafl-mergetm-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(dir.join("m.manifest.json"), "{\"schema\": \"eafl-ma").unwrap();
        let MergeDetail::NoManifest { quarantined } = merge_with_detail(&[dir.clone()]).unwrap()
        else {
            panic!("expected NoManifest")
        };
        assert_eq!(quarantined, 1);
        assert!(dir.join("m.manifest.json.quarantine").exists());
        let err = no_manifest_error(&[dir.clone()], quarantined).to_string();
        assert!(err.contains("manifest") && err.contains("quarantined"), "{err}");
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn quarantine_moves_aside_and_returns_destination() {
        let dir = std::env::temp_dir().join(format!("eafl-quar-{}", std::process::id()));
        std::fs::remove_dir_all(&dir).ok();
        std::fs::create_dir_all(&dir).unwrap();
        let victim = dir.join("x.summary.json");
        std::fs::write(&victim, "junk").unwrap();
        let dest = quarantine(&victim, "test").unwrap();
        assert_eq!(dest, dir.join("x.summary.json.quarantine"));
        assert!(!victim.exists());
        assert_eq!(std::fs::read_to_string(&dest).unwrap(), "junk");
        // A missing victim is reported, not fatal.
        assert!(quarantine(&victim, "already gone").is_none());
        std::fs::remove_dir_all(&dir).ok();
    }

    #[test]
    fn merge_rejects_disagreeing_manifests() {
        let base = std::env::temp_dir().join(format!("eafl-mergedis-{}", std::process::id()));
        let d0 = base.join("a");
        let d1 = base.join("b");
        std::fs::create_dir_all(&d0).unwrap();
        std::fs::create_dir_all(&d1).unwrap();
        let m = manifest();
        m.write(&d0).unwrap();
        let mut m2 = m.clone();
        m2.cells[0].seed = 9;
        m2.write(&d1).unwrap();
        let err = merge_dirs(&[d0, d1]).unwrap_err().to_string();
        assert!(err.contains("disagree"), "{err}");
        std::fs::remove_dir_all(&base).ok();
    }
}
