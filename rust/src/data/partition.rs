//! Non-IID client partition — paper §5 "Data Partitioning": each
//! learner is assigned samples from a random 10% of the labels (4 of
//! 35) while per-learner sample counts are uniform.

use crate::util::rng::Rng;

use crate::config::DataConfig;

use super::SampleRef;

/// One client's local dataset.
#[derive(Debug, Clone)]
pub struct ClientShard {
    /// The labels this client holds (paper: 4 of 35).
    pub labels: Vec<u16>,
    /// Sample references into the procedural dataset.
    pub samples: Vec<SampleRef>,
    /// Client-specific channel gain applied to every feature map
    /// (system-level heterogeneity beyond label skew).
    pub channel_gain: f32,
}

/// The full partition: per-client shards + IID test set.
#[derive(Debug, Clone)]
pub struct Partition {
    pub shards: Vec<ClientShard>,
    pub test: Vec<SampleRef>,
}

/// Deterministically partition `num_clients` clients per `cfg`.
///
/// Per-class sample indices are globally unique (a per-class counter),
/// so no two clients share a sample — disjoint local datasets, as in
/// a real federation.
pub fn partition_clients(
    cfg: &DataConfig,
    num_classes: usize,
    num_clients: usize,
) -> Partition {
    let mut rng = Rng::seed_from_u64(cfg.seed ^ 0xBEEF);
    let mut next_index = vec![0u32; num_classes];
    let labels_per_client = cfg.labels_per_client.min(num_classes);
    let shards = (0..num_clients)
        .map(|_| {
            let labels = {
                let mut all: Vec<u16> = (0..num_classes as u16).collect();
                rng.shuffle(&mut all);
                let mut l = all[..labels_per_client].to_vec();
                l.sort_unstable();
                l
            };
            let n = rng.gen_range_usize(cfg.min_samples, cfg.max_samples);
            let samples = (0..n)
                .map(|_| {
                    let &class = rng.choose(&labels).expect("labels non-empty");
                    let idx = next_index[class as usize];
                    next_index[class as usize] += 1;
                    (class, idx)
                })
                .collect();
            let channel_gain = rng.gen_range_f32(0.8, 1.2);
            ClientShard { labels, samples, channel_gain }
        })
        .collect();

    // Test refs live in a disjoint index range (>= 1e6, see synthetic.rs).
    let test = (0..cfg.test_samples)
        .map(|i| ((i % num_classes) as u16, 1_000_000 + (i / num_classes) as u32))
        .collect();

    Partition { shards, test }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> DataConfig {
        DataConfig::default()
    }

    #[test]
    fn deterministic_partition() {
        let a = partition_clients(&cfg(), 35, 20);
        let b = partition_clients(&cfg(), 35, 20);
        for (x, y) in a.shards.iter().zip(&b.shards) {
            assert_eq!(x.labels, y.labels);
            assert_eq!(x.samples, y.samples);
        }
    }

    #[test]
    fn each_client_has_exactly_4_of_35_labels() {
        let p = partition_clients(&cfg(), 35, 50);
        for shard in &p.shards {
            assert_eq!(shard.labels.len(), 4);
            let mut dedup = shard.labels.clone();
            dedup.dedup();
            assert_eq!(dedup.len(), 4, "labels must be distinct");
            for &(class, _) in &shard.samples {
                assert!(shard.labels.contains(&class));
            }
        }
    }

    #[test]
    fn sample_counts_within_range() {
        let c = cfg();
        let p = partition_clients(&c, 35, 100);
        for shard in &p.shards {
            assert!((c.min_samples..=c.max_samples).contains(&shard.samples.len()));
        }
    }

    #[test]
    fn samples_are_globally_disjoint() {
        let p = partition_clients(&cfg(), 35, 60);
        let mut seen = std::collections::HashSet::new();
        for shard in &p.shards {
            for s in &shard.samples {
                assert!(seen.insert(*s), "duplicate sample {s:?}");
            }
        }
    }

    #[test]
    fn test_set_disjoint_from_training() {
        let p = partition_clients(&cfg(), 35, 60);
        let train_max = p
            .shards
            .iter()
            .flat_map(|s| s.samples.iter().map(|&(_, i)| i))
            .max()
            .unwrap();
        let test_min = p.test.iter().map(|&(_, i)| i).min().unwrap();
        assert!(test_min > train_max);
        assert_eq!(p.test.len(), cfg().test_samples);
    }

    #[test]
    fn labels_per_client_clamped_to_num_classes() {
        let mut c = cfg();
        c.labels_per_client = 99;
        let p = partition_clients(&c, 10, 5);
        for shard in &p.shards {
            assert_eq!(shard.labels.len(), 10);
        }
    }
}
