//! In-tree substrates that would normally come from crates.io — this
//! build is fully offline (only the `xla` PJRT bridge and `anyhow` are
//! vendored), so per DESIGN.md §2 we implement them from scratch:
//!
//!  - [`rng`]  — deterministic xoshiro256++ RNG + the distributions the
//!    trace generators need (uniform, Bernoulli, normal, log-normal,
//!    Fisher–Yates shuffle).
//!  - [`json`] — minimal JSON parser/writer (manifest + summaries).
//!  - [`toml`] — TOML-subset parser/writer (experiment configs).
//!  - [`prop`] — tiny property-testing harness (randomized cases with
//!    seed reporting on failure) used by the invariant tests.
//!  - [`fixed`] — exact fixed-point accumulator backing the registry's
//!    incrementally maintained population aggregates.

pub mod fixed;
pub mod json;
pub mod prop;
pub mod rng;
pub mod toml;
