//! Metrics: Jain's fairness index, per-round time series, experiment
//! summaries and CSV/JSON emission — everything Figs. 3 & 4 plot.

mod fairness;
mod timeseries;

pub use fairness::{jain_index, jain_index_from_moments};
pub use timeseries::{MetricsLog, RoundRecord, Summary};
